//! Synthetic fMoW-like dataset (DESIGN.md §3 Substitutions).
//!
//! Every sample is defined by compact metadata (class, lat/lon, noise seed);
//! pixels are materialized on demand so a 360k-sample dataset costs MBs, not
//! GBs — mirroring how real satellite imagery stays on the satellite until
//! batched into training.
//!
//! Class-conditional structure: each class owns a 2-D sinusoidal texture
//! (frequency pair + per-channel phase + color mean) drawn from a
//! class-seeded PRNG. The frozen patch-embedding + dense head of the L2
//! model separates these textures well above chance but per-sample Gaussian
//! noise keeps accuracy climbing gradually, like the paper's fMoW curves.
//! Geography: each class is concentrated in a few "home" UTM zones, so the
//! Non-IID partitioner induces label skew exactly as the paper describes.

use crate::data::utm::{utm_cell, N_BANDS};
use crate::rng::Rng;

/// Image height [px].
pub const IMG_H: usize = 32;
/// Image width [px].
pub const IMG_W: usize = 32;
/// Image channels.
pub const IMG_C: usize = 3;
/// Flat pixels per image.
pub const IMG_DIM: usize = IMG_H * IMG_W * IMG_C;
/// Class count (fMoW has 62).
pub const NUM_CLASSES: usize = 62;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Training-split size.
    pub n_train: usize,
    /// Validation-split size.
    pub n_val: usize,
    /// Classes to generate (≤ [`NUM_CLASSES`]).
    pub num_classes: usize,
    /// Per-pixel Gaussian noise std (task difficulty knob).
    pub noise_sigma: f32,
    /// Home UTM zones per class (geographic concentration).
    pub home_zones_per_class: usize,
    /// Generator seed.
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            n_train: 19_100, // 100 per satellite at K=191 (scaled fMoW)
            n_val: 2_048,
            num_classes: NUM_CLASSES,
            noise_sigma: 0.8,
            home_zones_per_class: 3,
            seed: 2022,
        }
    }
}

/// Sample metadata; pixels are derived, not stored.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// Class label.
    pub class: u16,
    /// Capture latitude [deg].
    pub lat_deg: f32,
    /// Capture longitude [deg].
    pub lon_deg: f32,
    /// Per-sample pixel-noise seed.
    pub noise_seed: u64,
}

impl Sample {
    /// 2-D UTM cell (longitude zone × latitude band) — the Non-IID key.
    pub fn utm_cell(&self) -> usize {
        utm_cell(self.lat_deg as f64, self.lon_deg as f64)
    }
}

/// Per-class texture parameters (deterministic from the dataset seed).
#[derive(Clone, Debug)]
struct ClassPattern {
    fx: f32,
    fy: f32,
    phase: [f32; IMG_C],
    mean: [f32; IMG_C],
    amp: f32,
    /// (zone 1..=60, band 0..N_BANDS) cells where this class occurs
    home_cells: Vec<(usize, usize)>,
}

/// The synthetic dataset: train + validation splits.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Generator parameters it was built from.
    pub cfg: SynthConfig,
    /// Training split.
    pub train: Vec<Sample>,
    /// Validation split.
    pub val: Vec<Sample>,
    patterns: Vec<ClassPattern>,
}

impl Dataset {
    /// Generate the dataset deterministically from `cfg.seed`.
    pub fn generate(cfg: SynthConfig) -> Self {
        assert!(cfg.num_classes <= NUM_CLASSES);
        let mut rng = Rng::new(cfg.seed);
        let patterns: Vec<ClassPattern> = (0..cfg.num_classes)
            .map(|c| Self::class_pattern(c, &mut rng, &cfg))
            .collect();
        let gen_split = |n: usize, rng: &mut Rng| -> Vec<Sample> {
            (0..n)
                .map(|_| {
                    let class = rng.gen_range(0, cfg.num_classes) as u16;
                    let p = &patterns[class as usize];
                    // place inside one of the class's home cells
                    let (zone, band) = p.home_cells[rng.gen_range(0, p.home_cells.len())];
                    let zone_lon0 = -180.0 + 6.0 * (zone as f64 - 1.0);
                    let lon = zone_lon0 + rng.gen_f64(0.0, 6.0);
                    let band_lat0 = -80.0 + 8.0 * band as f64;
                    let lat = (band_lat0 + rng.gen_f64(0.0, 8.0)).clamp(-55.0, 70.0);
                    Sample {
                        class,
                        lat_deg: lat as f32,
                        lon_deg: lon as f32,
                        noise_seed: rng.next_u64(),
                    }
                })
                .collect()
        };
        let train = gen_split(cfg.n_train, &mut rng);
        let val = gen_split(cfg.n_val, &mut rng);
        Dataset { cfg, train, val, patterns }
    }

    fn class_pattern(c: usize, master: &mut Rng, cfg: &SynthConfig) -> ClassPattern {
        let mut r = master.split(c as u64 + 1);
        ClassPattern {
            fx: 1.0 + 7.0 * r.next_f32(),
            fy: 1.0 + 7.0 * r.next_f32(),
            phase: [
                r.gen_f64(0.0, std::f64::consts::TAU) as f32,
                r.gen_f64(0.0, std::f64::consts::TAU) as f32,
                r.gen_f64(0.0, std::f64::consts::TAU) as f32,
            ],
            mean: [
                r.gen_f64(-0.5, 0.5) as f32,
                r.gen_f64(-0.5, 0.5) as f32,
                r.gen_f64(-0.5, 0.5) as f32,
            ],
            amp: 0.6 + 0.4 * r.next_f32(),
            home_cells: (0..cfg.home_zones_per_class)
                .map(|_| {
                    // bands 3..=18 keep samples within the populated
                    // latitudes (−55°..70°) like fMoW's footprint
                    let zone = r.gen_range(1, 61);
                    let band = r.gen_range(3, (N_BANDS - 1).min(18) + 1);
                    (zone, band)
                })
                .collect(),
        }
    }

    /// Materialize pixels for one sample: flat [IMG_DIM] f32 row-major
    /// (h, w, c) — matches the L2 model's `_patchify` layout.
    pub fn materialize(&self, s: &Sample) -> Vec<f32> {
        let p = &self.patterns[s.class as usize];
        let mut noise = Rng::new(s.noise_seed);
        let mut img = vec![0f32; IMG_DIM];
        let tau = std::f64::consts::TAU as f32;
        for i in 0..IMG_H {
            for j in 0..IMG_W {
                let arg = tau * (p.fx * i as f32 / IMG_H as f32 + p.fy * j as f32 / IMG_W as f32);
                for ch in 0..IMG_C {
                    let v = p.mean[ch]
                        + p.amp * (arg + p.phase[ch]).sin()
                        + noise.normal_f32(0.0, self.cfg.noise_sigma);
                    img[(i * IMG_W + j) * IMG_C + ch] = v;
                }
            }
        }
        img
    }

    /// Build a flat batch (xs [n*IMG_DIM], ys [n] as f32 class ids).
    pub fn make_batch(&self, split: &[Sample], indices: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut xs = Vec::with_capacity(indices.len() * IMG_DIM);
        let mut ys = Vec::with_capacity(indices.len());
        for &i in indices {
            let s = &split[i];
            xs.extend_from_slice(&self.materialize(s));
            ys.push(s.class as f32);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::generate(SynthConfig {
            n_train: 200,
            n_val: 50,
            ..Default::default()
        })
    }

    #[test]
    fn split_sizes() {
        let d = tiny();
        assert_eq!(d.train.len(), 200);
        assert_eq!(d.val.len(), 50);
    }

    #[test]
    fn deterministic_from_seed() {
        let a = tiny();
        let b = tiny();
        for (x, y) in a.train.iter().zip(b.train.iter()) {
            assert_eq!(x.class, y.class);
            assert_eq!(x.noise_seed, y.noise_seed);
        }
        assert_eq!(a.materialize(&a.train[0]), b.materialize(&b.train[0]));
    }

    #[test]
    fn classes_in_range() {
        let d = tiny();
        assert!(d.train.iter().all(|s| (s.class as usize) < d.cfg.num_classes));
    }

    #[test]
    fn images_have_expected_shape_and_scale() {
        let d = tiny();
        let img = d.materialize(&d.train[0]);
        assert_eq!(img.len(), IMG_DIM);
        let mean: f32 = img.iter().sum::<f32>() / IMG_DIM as f32;
        assert!(mean.abs() < 2.0, "mean={mean}");
        assert!(img.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn same_class_images_correlated_more_than_cross_class() {
        let d = Dataset::generate(SynthConfig {
            n_train: 500,
            n_val: 10,
            noise_sigma: 0.3,
            ..Default::default()
        });
        // pick two samples of one class and one of another
        let a = d.train.iter().position(|s| s.class == 0).unwrap();
        let b = d.train.iter().rposition(|s| s.class == 0).unwrap();
        let c = d.train.iter().position(|s| s.class == 1).unwrap();
        assert_ne!(a, b);
        let corr = |x: &[f32], y: &[f32]| -> f32 {
            let n = x.len() as f32;
            let mx = x.iter().sum::<f32>() / n;
            let my = y.iter().sum::<f32>() / n;
            let cov: f32 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
            let vx: f32 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
            let vy: f32 = y.iter().map(|b| (b - my) * (b - my)).sum();
            cov / (vx.sqrt() * vy.sqrt())
        };
        let ia = d.materialize(&d.train[a]);
        let ib = d.materialize(&d.train[b]);
        let ic = d.materialize(&d.train[c]);
        assert!(corr(&ia, &ib) > corr(&ia, &ic) + 0.1);
    }

    #[test]
    fn geography_concentrated_in_home_cells() {
        let d = Dataset::generate(SynthConfig {
            n_train: 2000,
            n_val: 10,
            ..Default::default()
        });
        // each class's samples occupy at most home_zones_per_class distinct
        // cells (clamping at ±55/70 can merge edge cells, never add)
        for c in 0..5u16 {
            let mut cells: Vec<usize> = d
                .train
                .iter()
                .filter(|s| s.class == c)
                .map(|s| s.utm_cell())
                .collect();
            cells.sort_unstable();
            cells.dedup();
            assert!(
                !cells.is_empty() && cells.len() <= d.cfg.home_zones_per_class,
                "class {c} spread over {} cells",
                cells.len()
            );
        }
    }

    #[test]
    fn batch_layout() {
        let d = tiny();
        let (xs, ys) = d.make_batch(&d.train, &[0, 3, 5]);
        assert_eq!(xs.len(), 3 * IMG_DIM);
        assert_eq!(ys.len(), 3);
        assert_eq!(ys[1], d.train[3].class as f32);
        assert_eq!(xs[..IMG_DIM], d.materialize(&d.train[0])[..]);
    }
}
