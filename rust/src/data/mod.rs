//! Dataset substrate: synthetic fMoW-like imagery + the paper's IID /
//! Non-IID partitioners (§4.1).
//!
//! Substitution (DESIGN.md §3): the real fMoW dataset (360k 224×224 images,
//! 62 classes, geolocated) is not available offline; `synth` generates a
//! procedurally-defined 62-class 32×32×3 dataset where every sample carries
//! a lat/lon. Class-conditional spatial patterns make the task learnable by
//! the frozen-extractor + dense-head model but not trivial, and classes are
//! geographically concentrated so the UTM-zone partitioner induces the
//! paper's Non-IID label skew.

pub mod partition;
pub mod synth;
pub mod utm;

pub use partition::{cell_visits, partition_iid, partition_noniid, Partition};
pub use synth::{Dataset, Sample, SynthConfig};
pub use utm::{utm_band, utm_cell, utm_zone};
