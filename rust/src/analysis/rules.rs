//! The determinism-contract rules behind `fedspace lint` (ADR-0011).
//!
//! Each rule encodes one invariant the repo's bit-identity guarantees
//! (ADR-0002) rest on. The rules are *repo-specific by design*: they know
//! which modules are deterministic, which enum is the event stream and
//! which test is the section registry — that knowledge is exactly what a
//! general-purpose linter cannot have and why one stray `HashMap` or
//! wall-clock read can slip through review. Structural rules locate their
//! anchors by *content* (`enum RunEvent`, `fn every_section_…`), not by
//! path, so moving a module does not silently disarm them.
//!
//! Every rule reports through [`Emitter::emit`], which consults the
//! pragma layer: `// lint: allow(<rule>): <reason>` on the same line or
//! the line above suppresses the finding (and is counted, so CI can pin
//! that suppressions don't balloon).

use super::tokens::{skip_group, FileTokens, Tok, TokKind};

/// One lint finding, addressed by scan-relative path and 1-based line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Rule identifier (one of [`RULES`], or `pragma` for the meta-rule).
    pub rule: &'static str,
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based line (0 = whole-file/structural finding).
    pub line: usize,
    /// Human-readable description of the violation.
    pub message: String,
}

/// One tokenized file under the scan root.
#[derive(Debug)]
pub struct FileScan {
    /// Path relative to the scan root, `/`-separated.
    pub rel: String,
    /// Token stream + pragmas.
    pub tokens: FileTokens,
}

/// The rule registry: `(id, summary)` for every determinism rule, in
/// report order. The `pragma` meta-rule (malformed / unknown-rule
/// pragmas) is always on and not listed here.
pub const RULES: [(&str, &str); 6] = [
    ("wall-clock", "Instant::now/SystemTime only at pragma-annotated sites"),
    ("hash-order", "no HashMap/HashSet in deterministic modules"),
    ("rng-stream", "seed xor derivations must use distinct named *_STREAM consts"),
    ("event-coverage", "every RunEvent variant folded into TraceSink::apply and to_json, no wildcard"),
    ("float-reduce", "no unblocked f32 sum/fold reductions in fl/ and sim/"),
    ("section-registry", "every SectionSpec impl present in the generic round-trip test"),
];

/// Module prefixes (first path component under the scan root) whose
/// iteration order feeds the bit-identical trace — the `hash-order` scope.
const DETERMINISTIC_MODULES: [&str; 6] = ["sim", "fl", "connectivity", "sched", "orbit", "cfg"];

/// Collects findings, counting pragma suppressions.
#[derive(Debug, Default)]
pub struct Emitter {
    /// Live findings (not suppressed).
    pub findings: Vec<Finding>,
    /// Findings suppressed by a pragma.
    pub suppressed: usize,
}

impl Emitter {
    /// Report a finding unless a pragma at `line` (or the line above)
    /// allows `rule` in this file.
    fn emit(&mut self, scan: &FileScan, rule: &'static str, line: usize, message: String) {
        if scan.tokens.allows(rule, line) {
            self.suppressed += 1;
        } else {
            self.findings.push(Finding { rule, file: scan.rel.clone(), line, message });
        }
    }

    /// Report a non-suppressible finding (the pragma meta-rule itself).
    fn emit_hard(&mut self, file: &str, rule: &'static str, line: usize, message: String) {
        self.findings.push(Finding { rule, file: file.to_string(), line, message });
    }
}

/// Run every rule over the scan set. Findings come back sorted by
/// (file, line, rule) so output order never depends on rule order.
pub fn check_all(files: &[FileScan]) -> Emitter {
    let mut em = Emitter::default();
    check_pragmas(files, &mut em);
    check_wall_clock(files, &mut em);
    check_hash_order(files, &mut em);
    check_rng_stream(files, &mut em);
    check_event_coverage(files, &mut em);
    check_float_reduce(files, &mut em);
    check_section_registry(files, &mut em);
    em.findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    em
}

/// Runtime (non-test) tokens of a file.
fn live(scan: &FileScan) -> impl Iterator<Item = (usize, &Tok)> + '_ {
    scan.tokens.toks.iter().enumerate().filter(|(_, t)| !t.in_test)
}

fn ident_at(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
}

fn punct_at(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
}

/// Meta-rule: malformed pragmas and pragmas naming unknown rules are
/// findings — a typo in a pragma must not silently un-suppress a site.
fn check_pragmas(files: &[FileScan], em: &mut Emitter) {
    for scan in files {
        for &line in &scan.tokens.malformed_pragmas {
            em.emit_hard(
                &scan.rel,
                "pragma",
                line,
                "malformed lint pragma; expected `// lint: allow(<rule>): <reason>` \
                 with a non-empty reason"
                    .to_string(),
            );
        }
        for p in &scan.tokens.pragmas {
            if !RULES.iter().any(|(id, _)| *id == p.rule) {
                em.emit_hard(
                    &scan.rel,
                    "pragma",
                    p.line,
                    format!("pragma allows unknown rule `{}`", p.rule),
                );
            }
        }
    }
}

/// `wall-clock`: `Instant::now` / `SystemTime` reads are nondeterministic
/// by definition; ADR-0002 exempts only the Timing/ServeReport/bench
/// surfaces, and those sites carry pragmas.
fn check_wall_clock(files: &[FileScan], em: &mut Emitter) {
    for scan in files {
        let toks = &scan.tokens.toks;
        for (i, t) in live(scan) {
            if t.kind != TokKind::Ident {
                continue;
            }
            if t.text == "Instant"
                && punct_at(toks, i + 1, ":")
                && punct_at(toks, i + 2, ":")
                && ident_at(toks, i + 3, "now")
            {
                em.emit(
                    scan,
                    "wall-clock",
                    t.line,
                    "Instant::now() outside a pragma-annotated timing site; wall-clock \
                     reads are identity-exempt only under ADR-0002"
                        .to_string(),
                );
            } else if t.text == "SystemTime" {
                em.emit(
                    scan,
                    "wall-clock",
                    t.line,
                    "SystemTime is wall-clock state; deterministic code derives time \
                     from the step index"
                        .to_string(),
                );
            }
        }
    }
}

/// `hash-order`: `HashMap`/`HashSet` iteration order is randomized per
/// process, so any walk over one inside a deterministic module can leak
/// into the trace. `BTreeMap`/`BTreeSet`/sorted `Vec` are the sanctioned
/// shapes (and the only ones the repo uses today — this rule locks that
/// in).
fn check_hash_order(files: &[FileScan], em: &mut Emitter) {
    for scan in files {
        let in_scope = scan
            .rel
            .split('/')
            .next()
            .is_some_and(|first| DETERMINISTIC_MODULES.contains(&first));
        if !in_scope {
            continue;
        }
        for (_, t) in live(scan) {
            if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
                em.emit(
                    scan,
                    "hash-order",
                    t.line,
                    format!(
                        "{} in a deterministic module; iteration order is per-process \
                         random — use BTreeMap/BTreeSet or a sorted Vec",
                        t.text
                    ),
                );
            }
        }
    }
}

/// `rng-stream`: independent RNG streams are derived as
/// `seed ^ <NAME>_STREAM` (ADR-0002). A raw literal xor hides the stream
/// from review; two streams sharing a constant silently correlate. The
/// rule checks both: the derivation *shape* per site, and pairwise
/// distinctness of every `*_STREAM` const numerically, across files.
fn check_rng_stream(files: &[FileScan], em: &mut Emitter) {
    fn seedish(t: &Tok) -> bool {
        t.kind == TokKind::Ident && t.text.to_ascii_lowercase().contains("seed")
    }
    fn unnamed_ident(t: &Tok) -> bool {
        t.kind == TokKind::Ident && !t.text.ends_with("_STREAM")
    }
    // stream-const declarations: `const X_STREAM: u64 = <int>;`
    let mut decls: Vec<(String, u64, String, usize)> = Vec::new(); // (name, value, file, line)
    for scan in files {
        let toks = &scan.tokens.toks;
        for (i, t) in live(scan) {
            // derivation sites
            if t.kind == TokKind::Punct && t.text == "^" && i > 0 {
                let prev = &toks[i - 1];
                let next = toks.get(i + 1);
                let lit = |t: &Tok| t.kind == TokKind::Int;
                let raw = (seedish(prev) && next.is_some_and(lit))
                    || (next.is_some_and(seedish) && lit(prev));
                let unnamed = seedish(prev) && next.is_some_and(unnamed_ident);
                if raw {
                    em.emit(
                        scan,
                        "rng-stream",
                        t.line,
                        "seed xor with a raw literal; derive streams through a named \
                         *_STREAM const so collisions are checkable"
                            .to_string(),
                    );
                } else if unnamed {
                    em.emit(
                        scan,
                        "rng-stream",
                        t.line,
                        "seed xor with a non-stream identifier; stream constants must \
                         be named *_STREAM"
                            .to_string(),
                    );
                }
            }
            // const declarations: `const NAME_STREAM : <ty> = <int> ;`
            let named = toks.get(i + 1);
            let stream_name = named.is_some_and(|n| {
                n.kind == TokKind::Ident && n.text.ends_with("_STREAM")
            });
            if ident_at(toks, i, "const") && stream_name && punct_at(toks, i + 2, ":") {
                let name = toks[i + 1].text.clone();
                let mut j = i + 3;
                while j < toks.len() && !punct_at(toks, j, "=") && !punct_at(toks, j, ";") {
                    j += 1;
                }
                if punct_at(toks, j, "=") {
                    if let Some(v) = toks.get(j + 1).and_then(|t| parse_int(&t.text)) {
                        decls.push((name, v, scan.rel.clone(), toks[i + 1].line));
                    }
                }
            }
        }
    }
    // pairwise distinctness, reported at the later declaration
    decls.sort_by(|a, b| (a.2.as_str(), a.3).cmp(&(b.2.as_str(), b.3)));
    for (k, (name, value, file, line)) in decls.iter().enumerate() {
        if let Some((first_name, _, first_file, first_line)) =
            decls[..k].iter().find(|(_, v, _, _)| v == value)
        {
            let scan = files.iter().find(|s| &s.rel == file).expect("decl file");
            em.emit(
                scan,
                "rng-stream",
                *line,
                format!(
                    "{name} = {value:#x} collides with {first_name} \
                     ({first_file}:{first_line}); RNG streams must be pairwise distinct"
                ),
            );
        }
    }
}

/// Parse a Rust integer literal (underscores, radix prefixes, ignores a
/// trailing type suffix).
fn parse_int(text: &str) -> Option<u64> {
    let t: String = text.chars().filter(|&c| c != '_').collect();
    let (radix, digits) = match t.get(..2) {
        Some("0x") | Some("0X") => (16, &t[2..]),
        Some("0o") => (8, &t[2..]),
        Some("0b") => (2, &t[2..]),
        _ => (10, t.as_str()),
    };
    let end = digits
        .find(|c: char| !c.is_digit(radix))
        .unwrap_or(digits.len());
    if end == 0 {
        return None;
    }
    u64::from_str_radix(&digits[..end], radix).ok()
}

/// `event-coverage`: the trace is a fold over the event stream, so a
/// `RunEvent` variant that never reaches `TraceSink::apply` (or the
/// artifact serializer `RunEvent::to_json`) is invisible to every
/// downstream consumer — and a wildcard arm would let the *next* variant
/// slip through silently. Anchored by content: any file declaring
/// `enum RunEvent` is checked.
fn check_event_coverage(files: &[FileScan], em: &mut Emitter) {
    for scan in files {
        let toks = &scan.tokens.toks;
        let Some(enum_at) = find_seq(toks, &["enum", "RunEvent", "{"]) else { continue };
        let variants = enum_variants(toks, enum_at + 2);
        // TraceSink::apply — the single trace mutation site
        match fn_body(toks, "apply", None) {
            Some((lo, hi)) => {
                check_match_coverage(scan, toks, lo, hi, &variants, "TraceSink::apply", em);
            }
            None => em.emit(
                scan,
                "event-coverage",
                toks[enum_at].line,
                "enum RunEvent declared but no `fn apply` (TraceSink) found in this file"
                    .to_string(),
            ),
        }
        // RunEvent::to_json — the artifact serializer (to_json also exists
        // on RunArtifact, so resolve it inside `impl RunEvent`)
        match fn_body(toks, "to_json", Some("RunEvent")) {
            Some((lo, hi)) => {
                check_match_coverage(scan, toks, lo, hi, &variants, "RunEvent::to_json", em);
            }
            None => em.emit(
                scan,
                "event-coverage",
                toks[enum_at].line,
                "enum RunEvent declared but no `impl RunEvent { fn to_json }` found in \
                 this file"
                    .to_string(),
            ),
        }
    }
}

/// Every variant must appear as `RunEvent::<V>` inside `[lo, hi)`, and the
/// body may not contain a wildcard arm (`_ =>`).
fn check_match_coverage(
    scan: &FileScan,
    toks: &[Tok],
    lo: usize,
    hi: usize,
    variants: &[(String, usize)],
    site: &str,
    em: &mut Emitter,
) {
    let mut seen: Vec<&str> = Vec::new();
    for i in lo..hi {
        if ident_at(toks, i, "RunEvent")
            && punct_at(toks, i + 1, ":")
            && punct_at(toks, i + 2, ":")
        {
            if let Some(v) = toks.get(i + 3) {
                if v.kind == TokKind::Ident {
                    seen.push(v.text.as_str());
                }
            }
        }
        if ident_at(toks, i, "_") && punct_at(toks, i + 1, "=") && punct_at(toks, i + 2, ">") {
            em.emit(
                scan,
                "event-coverage",
                toks[i].line,
                format!(
                    "wildcard arm in {site}; every RunEvent variant must be matched \
                     explicitly so new variants are folded in deliberately"
                ),
            );
        }
    }
    for (v, line) in variants {
        if !seen.iter().any(|s| s == v) {
            em.emit(
                scan,
                "event-coverage",
                *line,
                format!("RunEvent::{v} is not handled in {site}"),
            );
        }
    }
}

/// Collect `(variant, line)` of an enum whose body opens at `toks[open]`.
fn enum_variants(toks: &[Tok], open: usize) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let end = skip_group(toks, open, "{", "}");
    let mut i = open + 1;
    while i + 1 < end {
        let t = &toks[i];
        if t.text == "#" && punct_at(toks, i + 1, "[") {
            i = skip_group(toks, i + 1, "[", "]");
        } else if t.kind == TokKind::Ident {
            out.push((t.text.clone(), t.line));
            i += 1;
            if punct_at(toks, i, "{") {
                i = skip_group(toks, i, "{", "}");
            } else if punct_at(toks, i, "(") {
                i = skip_group(toks, i, "(", ")");
            }
            while i < end && !punct_at(toks, i, ",") {
                i += 1;
            }
            i += 1;
        } else {
            i += 1;
        }
    }
    out
}

/// Token span `(lo, hi)` of the body of `fn <name>`, optionally resolved
/// inside `impl <owner> { … }`. Searches test regions too (the section
/// registry lives in one); callers on runtime paths pass the whole file.
fn fn_body(toks: &[Tok], name: &str, owner: Option<&str>) -> Option<(usize, usize)> {
    let (lo, hi) = match owner {
        None => (0, toks.len()),
        Some(owner) => {
            let at = find_seq(toks, &["impl", owner, "{"])?;
            let end = skip_group(toks, at + 2, "{", "}");
            (at + 2, end)
        }
    };
    let mut i = lo;
    while i + 1 < hi {
        if ident_at(toks, i, "fn") && ident_at(toks, i + 1, name) {
            let mut j = i + 2;
            while j < hi && !punct_at(toks, j, "{") {
                j += 1;
            }
            if j < hi {
                return Some((j, skip_group(toks, j, "{", "}")));
            }
        }
        i += 1;
    }
    None
}

/// First index where the token texts `pat` appear consecutively.
fn find_seq(toks: &[Tok], pat: &[&str]) -> Option<usize> {
    (0..toks.len().saturating_sub(pat.len() - 1))
        .find(|&i| pat.iter().enumerate().all(|(k, p)| toks[i + k].text == *p))
}

/// `float-reduce`: f32 addition is non-associative, so an iterator
/// `sum()`/`fold()` over f32 in the aggregation/simulation path bakes the
/// iteration shape into the result bits. The blocked-accumulate helpers
/// (fl/server.rs) use indexed block loops precisely so the summation
/// order is pinned; everything else must accumulate in f64 or carry a
/// pragma. Detected shapes: `sum::<f32>()`, `.fold(<f32 literal>, …)`,
/// and `let …: f32 = ….sum();`.
fn check_float_reduce(files: &[FileScan], em: &mut Emitter) {
    for scan in files {
        let first = scan.rel.split('/').next().unwrap_or("");
        if first != "fl" && first != "sim" {
            continue;
        }
        let toks = &scan.tokens.toks;
        for (i, t) in live(scan) {
            if t.kind != TokKind::Ident {
                continue;
            }
            let msg = |what: &str| {
                format!(
                    "{what} reduces f32 in iteration order; accumulate in f64 or use \
                     the blocked helpers (ADR-0002)"
                )
            };
            if t.text == "sum"
                && punct_at(toks, i + 1, ":")
                && punct_at(toks, i + 2, ":")
                && punct_at(toks, i + 3, "<")
                && ident_at(toks, i + 4, "f32")
            {
                em.emit(scan, "float-reduce", t.line, msg("sum::<f32>()"));
            } else if t.text == "fold"
                && i > 0
                && punct_at(toks, i - 1, ".")
                && punct_at(toks, i + 1, "(")
                && toks.get(i + 2).is_some_and(|a| {
                    a.kind == TokKind::Float && a.text.ends_with("f32")
                })
            {
                em.emit(scan, "float-reduce", t.line, msg(".fold(…f32, …)"));
            } else if t.text == "sum"
                && i > 0
                && punct_at(toks, i - 1, ".")
                && punct_at(toks, i + 1, "(")
                && punct_at(toks, i + 2, ")")
                && stmt_ascribes_f32(toks, i)
            {
                em.emit(scan, "float-reduce", t.line, msg("`: f32` sum()"));
            }
        }
    }
}

/// Walk back from token `i` to the start of its statement (`;`, `{`, `}`)
/// looking for a `: f32` type ascription.
fn stmt_ascribes_f32(toks: &[Tok], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = &toks[j];
        if t.kind == TokKind::Punct && (t.text == ";" || t.text == "{" || t.text == "}") {
            return false;
        }
        if t.kind == TokKind::Punct
            && t.text == ":"
            && !punct_at(toks, j.wrapping_sub(1), ":")
            && !punct_at(toks, j + 1, ":")
            && ident_at(toks, j + 1, "f32")
        {
            return true;
        }
    }
    false
}

/// `section-registry`: every `impl SectionSpec for X` must appear in the
/// generic round-trip test (`every_section_round_trips_generically` in
/// cfg/section.rs) — the one test that proves a section's emit/parse/
/// validate lifecycle. An impl missing from the list ships an untested
/// TOML surface.
fn check_section_registry(files: &[FileScan], em: &mut Emitter) {
    // impl sites (runtime code)
    let mut impls: Vec<(String, usize, usize)> = Vec::new(); // (name, file idx, line)
    for (fi, scan) in files.iter().enumerate() {
        let toks = &scan.tokens.toks;
        for (i, t) in live(scan) {
            if t.kind == TokKind::Ident
                && t.text == "SectionSpec"
                && ident_at(toks, i + 1, "for")
                && toks.get(i + 2).is_some_and(|n| n.kind == TokKind::Ident)
                // `impl` may sit up to 10 tokens back when the trait is
                // path-qualified (`impl crate::cfg::section::SectionSpec for X`).
                && (0..=12).any(|back| i >= back && ident_at(toks, i - back, "impl"))
            {
                impls.push((toks[i + 2].text.clone(), fi, toks[i + 2].line));
            }
        }
    }
    if impls.is_empty() {
        return;
    }
    // the registry body (inside a #[cfg(test)] mod, searched deliberately)
    let registry: Option<Vec<&str>> = files.iter().find_map(|scan| {
        let toks = &scan.tokens.toks;
        let (lo, hi) = fn_body(toks, "every_section_round_trips_generically", None)?;
        Some(
            toks[lo..hi]
                .iter()
                .filter(|t| t.kind == TokKind::Ident)
                .map(|t| t.text.as_str())
                .collect(),
        )
    });
    let Some(listed) = registry else {
        let (name, fi, line) = &impls[0];
        em.emit(
            &files[*fi],
            "section-registry",
            *line,
            format!(
                "impl SectionSpec for {name} but the generic round-trip test \
                 (every_section_round_trips_generically) was not found in the scan"
            ),
        );
        return;
    };
    for (name, fi, line) in &impls {
        if !listed.iter().any(|l| l == name) {
            em.emit(
                &files[*fi],
                "section-registry",
                *line,
                format!(
                    "impl SectionSpec for {name} is missing from \
                     every_section_round_trips_generically — its TOML round-trip is \
                     untested"
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::tokens::tokenize;

    fn scan_one(rel: &str, src: &str) -> Vec<FileScan> {
        vec![FileScan { rel: rel.to_string(), tokens: tokenize(src) }]
    }

    fn rules_of(em: &Emitter) -> Vec<&'static str> {
        em.findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn wall_clock_fires_and_pragma_suppresses() {
        let em = check_all(&scan_one("app/x.rs", "let t = Instant::now();"));
        assert_eq!(rules_of(&em), vec!["wall-clock"]);
        assert_eq!(em.findings[0].line, 1);
        let em = check_all(&scan_one(
            "app/x.rs",
            "// lint: allow(wall-clock): bench timing\nlet t = Instant::now();",
        ));
        assert!(em.findings.is_empty(), "{:?}", em.findings);
        assert_eq!(em.suppressed, 1);
    }

    #[test]
    fn wall_clock_skips_tests_and_strings() {
        let src = "const M: &str = \"Instant::now\";\n#[cfg(test)]\nmod tests {\n    fn t() { let x = Instant::now(); }\n}";
        let em = check_all(&scan_one("app/x.rs", src));
        assert!(em.findings.is_empty(), "{:?}", em.findings);
    }

    #[test]
    fn hash_order_scoped_to_deterministic_modules() {
        let src = "use std::collections::HashMap;";
        let em = check_all(&scan_one("sim/state.rs", src));
        assert_eq!(rules_of(&em), vec!["hash-order"]);
        let em = check_all(&scan_one("app/state.rs", src));
        assert!(em.findings.is_empty());
    }

    #[test]
    fn rng_stream_shapes() {
        let em = check_all(&scan_one("fl/x.rs", "let r = Rng::new(seed ^ 0xBEEF);"));
        assert_eq!(rules_of(&em), vec!["rng-stream"]);
        let em = check_all(&scan_one("fl/x.rs", "let r = Rng::new(0xBEEF ^ run_seed);"));
        assert_eq!(rules_of(&em), vec!["rng-stream"]);
        let em = check_all(&scan_one("fl/x.rs", "let r = Rng::new(seed ^ SOME_CONST);"));
        assert_eq!(rules_of(&em), vec!["rng-stream"]);
        let ok = "pub const A_STREAM: u64 = 0xA;\nlet r = Rng::new(seed ^ A_STREAM);";
        let em = check_all(&scan_one("fl/x.rs", ok));
        assert!(em.findings.is_empty(), "{:?}", em.findings);
        // non-seed xors never fire
        let em = check_all(&scan_one("fl/x.rs", "let z = a ^ (b >> 30);"));
        assert!(em.findings.is_empty());
    }

    #[test]
    fn rng_stream_collision_detected_across_files() {
        let a = FileScan {
            rel: "a/one.rs".into(),
            tokens: tokenize("pub const A_STREAM: u64 = 0xC0DE;"),
        };
        let b = FileScan {
            rel: "b/two.rs".into(),
            tokens: tokenize("pub const B_STREAM: u64 = 0xC0DE;"),
        };
        let em = check_all(&[a, b]);
        assert_eq!(rules_of(&em), vec!["rng-stream"]);
        assert_eq!(em.findings[0].file, "b/two.rs");
        assert!(em.findings[0].message.contains("A_STREAM"));
    }

    #[test]
    fn event_coverage_missing_variant_and_wildcard() {
        let src = "\
pub enum RunEvent {\n\
    Alpha { x: usize },\n\
    Beta,\n\
}\n\
impl TraceSink {\n\
    pub fn apply(t: &mut T, e: &RunEvent) {\n\
        match e {\n\
            RunEvent::Alpha { .. } => {}\n\
            _ => {}\n\
        }\n\
    }\n\
}\n\
impl RunEvent {\n\
    pub fn to_json(&self) -> String {\n\
        match self {\n\
            RunEvent::Alpha { .. } => {}\n\
            RunEvent::Beta => {}\n\
        }\n\
    }\n\
}\n";
        let em = check_all(&scan_one("sim/events.rs", src));
        let rules = rules_of(&em);
        assert_eq!(rules, vec!["event-coverage", "event-coverage"], "{:?}", em.findings);
        // one wildcard finding (line 9), one missing-variant finding (Beta, line 3)
        assert!(em.findings.iter().any(|f| f.line == 3 && f.message.contains("Beta")));
        assert!(em.findings.iter().any(|f| f.line == 9 && f.message.contains("wildcard")));
    }

    #[test]
    fn event_coverage_clean_when_total() {
        let src = "\
pub enum RunEvent {\n\
    Alpha { x: usize },\n\
    Beta,\n\
}\n\
impl TraceSink {\n\
    pub fn apply(t: &mut T, e: &RunEvent) {\n\
        match e {\n\
            RunEvent::Alpha { .. } => {}\n\
            RunEvent::Beta => {}\n\
        }\n\
    }\n\
}\n\
impl RunEvent {\n\
    pub fn to_json(&self) -> String {\n\
        match self {\n\
            RunEvent::Alpha { .. } | RunEvent::Beta => {}\n\
        }\n\
    }\n\
}\n";
        let em = check_all(&scan_one("sim/events.rs", src));
        assert!(em.findings.is_empty(), "{:?}", em.findings);
    }

    #[test]
    fn float_reduce_shapes() {
        let em = check_all(&scan_one("fl/a.rs", "let s: f32 = xs.iter().sum();"));
        assert_eq!(rules_of(&em), vec!["float-reduce"]);
        let em = check_all(&scan_one("fl/a.rs", "let s = xs.iter().sum::<f32>();"));
        assert_eq!(rules_of(&em), vec!["float-reduce"]);
        let em = check_all(&scan_one("fl/a.rs", "let m = xs.iter().fold(0.0f32, |a, v| a + v);"));
        assert_eq!(rules_of(&em), vec!["float-reduce"]);
        // f64 accumulation and out-of-scope modules pass
        let em = check_all(&scan_one("fl/a.rs", "let s: f64 = xs.iter().sum();"));
        assert!(em.findings.is_empty(), "{:?}", em.findings);
        let em = check_all(&scan_one("sched/a.rs", "let s: f32 = xs.iter().sum();"));
        assert!(em.findings.is_empty());
        // a prior statement's `: f32` does not leak across `;`
        let em = check_all(&scan_one("fl/a.rs", "let a: f32 = 1.0; let s: f64 = xs.sum();"));
        assert!(em.findings.is_empty(), "{:?}", em.findings);
    }

    #[test]
    fn section_registry_missing_impl_detected() {
        let imp = FileScan {
            rel: "fl/foo.rs".into(),
            tokens: tokenize("impl crate::cfg::section::SectionSpec for FooSpec {}"),
        };
        let reg = FileScan {
            rel: "cfg/section.rs".into(),
            tokens: tokenize(
                "#[cfg(test)]\nmod tests {\n    fn every_section_round_trips_generically() {\n        roundtrip(BarSpec::default());\n    }\n}",
            ),
        };
        let em = check_all(&[imp, reg]);
        assert_eq!(rules_of(&em), vec!["section-registry"]);
        assert_eq!(em.findings[0].file, "fl/foo.rs");
        // and a listed impl passes
        let imp = FileScan {
            rel: "fl/foo.rs".into(),
            tokens: tokenize("impl SectionSpec for BarSpec {}"),
        };
        let reg = FileScan {
            rel: "cfg/section.rs".into(),
            tokens: tokenize(
                "fn every_section_round_trips_generically() { roundtrip(BarSpec::default()); }",
            ),
        };
        let em = check_all(&[imp, reg]);
        assert!(em.findings.is_empty(), "{:?}", em.findings);
    }

    #[test]
    fn pragma_meta_rule() {
        let em = check_all(&scan_one("app/x.rs", "// lint: allow(wall-clock)\n"));
        assert_eq!(rules_of(&em), vec!["pragma"]);
        let em = check_all(&scan_one("app/x.rs", "// lint: allow(no-such-rule): because\n"));
        assert_eq!(rules_of(&em), vec!["pragma"]);
    }
}
