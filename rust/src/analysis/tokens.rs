//! A lightweight Rust tokenizer for the `fedspace lint` pass (ADR-0011).
//!
//! This is deliberately *not* a parser: the determinism rules key off small,
//! local token shapes (`Instant :: now`, `seed ^ <literal>`,
//! `impl SectionSpec for X`), so a flat token stream with line numbers is
//! the right altitude — it cannot drift out of sync with the language the
//! way a hand-rolled grammar would, and it tokenizes the whole crate in
//! microseconds. What it *does* understand beyond raw lexing, because the
//! rules need it:
//!
//! - **comments** are skipped, but `// lint: allow(<rule>): <reason>`
//!   pragma comments are captured as [`Pragma`] records (the suppression
//!   layer every rule shares);
//! - **`#[cfg(test)] mod …`** bodies are marked token-by-token
//!   ([`Tok::in_test`]): the determinism contract governs runtime paths,
//!   so rules skip test regions unless they explicitly opt in (the
//!   section-registry rule reads the round-trip list *inside* a test mod);
//! - **strings / chars / lifetimes / numbers** are single tokens, so rule
//!   patterns can never fire inside a literal.

/// Lexical class of one token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (including a bare `_`).
    Ident,
    /// Integer literal (any radix, `_` separators, optional type suffix).
    Int,
    /// Float literal (optional type suffix).
    Float,
    /// String literal (`"…"`, `r"…"`, `r#"…"#`, byte variants).
    Str,
    /// Char literal.
    Char,
    /// Lifetime (`'a`).
    Lifetime,
    /// Single punctuation character (`::` is two `:` tokens).
    Punct,
}

/// One token with its source position.
#[derive(Clone, Debug)]
pub struct Tok {
    /// Lexical class.
    pub kind: TokKind,
    /// Verbatim source text (for [`TokKind::Str`], includes the quotes).
    pub text: String,
    /// 1-based source line the token starts on.
    pub line: usize,
    /// The token sits inside a `#[cfg(test)] mod` body.
    pub in_test: bool,
}

/// One `// lint: allow(<rule>): <reason>` pragma comment.
#[derive(Clone, Debug)]
pub struct Pragma {
    /// 1-based line of the comment.
    pub line: usize,
    /// Rule the pragma suppresses (the text inside `allow(…)`).
    pub rule: String,
    /// Justification after the closing `):` — must be non-empty.
    pub reason: String,
}

/// Tokenized source of one file.
#[derive(Clone, Debug, Default)]
pub struct FileTokens {
    /// Tokens in source order.
    pub toks: Vec<Tok>,
    /// Pragma comments in source order.
    pub pragmas: Vec<Pragma>,
    /// Lines holding a comment that *looks* like a lint pragma but failed
    /// to parse (missing reason, malformed `allow(…)`) — surfaced as
    /// findings by the pragma meta-rule so typos cannot silently
    /// un-suppress a site.
    pub malformed_pragmas: Vec<usize>,
}

impl FileTokens {
    /// Is a finding of `rule` at `line` suppressed by a pragma? A pragma
    /// covers its own line (trailing-comment form) and the line directly
    /// below it (standalone-comment form).
    pub fn allows(&self, rule: &str, line: usize) -> bool {
        self.pragmas.iter().any(|p| p.rule == rule && (p.line == line || p.line + 1 == line))
    }
}

/// Tokenize one Rust source file. Never fails: unrecognized bytes become
/// single-char [`TokKind::Punct`] tokens, which no rule pattern matches.
pub fn tokenize(src: &str) -> FileTokens {
    let mut out = FileTokens::default();
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0;
    let mut line = 1;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && i + 1 < n && b[i + 1] == '/' {
            // line comment (incl. doc comments): capture, check for pragma
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            scan_pragma(&text, line, &mut out);
        } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
            // block comment, nestable
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if c == '"' || (c == 'r' && raw_string_len(&b[i..]).is_some()) {
            let (text, lines) = scan_string(&b[i..]);
            let len = text.chars().count();
            out.toks.push(Tok { kind: TokKind::Str, text, line, in_test: false });
            line += lines;
            i += len;
        } else if c == 'b' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '\'') {
            // byte string / byte char: emit the `b` as part of the literal
            let (text, lines) = if b[i + 1] == '"' {
                let (t, l) = scan_string(&b[i + 1..]);
                (format!("b{t}"), l)
            } else {
                (format!("b{}", scan_char(&b[i + 1..])), 0)
            };
            let kind = if b[i + 1] == '"' { TokKind::Str } else { TokKind::Char };
            let len = text.chars().count();
            out.toks.push(Tok { kind, text, line, in_test: false });
            line += lines;
            i += len;
        } else if c == '\'' {
            // lifetime or char literal: a lifetime is `'` + ident not
            // closed by another quote right after one symbol
            if is_lifetime(&b[i..]) {
                let start = i;
                i += 1;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[start..i].iter().collect(),
                    line,
                    in_test: false,
                });
            } else {
                let text = scan_char(&b[i..]);
                let len = text.chars().count();
                out.toks.push(Tok { kind: TokKind::Char, text, line, in_test: false });
                i += len;
            }
        } else if c.is_ascii_digit() {
            let start = i;
            let mut kind = TokKind::Int;
            if c == '0' && i + 1 < n && matches!(b[i + 1], 'x' | 'o' | 'b') {
                i += 2;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                    i += 1;
                }
                // fractional part — but not `1..2` (range) or `1.max(…)`
                if i + 1 < n
                    && b[i] == '.'
                    && b[i + 1].is_ascii_digit()
                {
                    kind = TokKind::Float;
                    i += 1;
                    while i < n && (b[i].is_ascii_digit() || b[i] == '_') {
                        i += 1;
                    }
                }
                // exponent and/or type suffix (f32, f64, u64, usize…)
                if i < n && (b[i] == 'e' || b[i] == 'E') && kind == TokKind::Float {
                    i += 1;
                    if i < n && (b[i] == '+' || b[i] == '-') {
                        i += 1;
                    }
                    while i < n && b[i].is_ascii_digit() {
                        i += 1;
                    }
                }
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    if b[i] == 'f' {
                        kind = TokKind::Float;
                    }
                    i += 1;
                }
            }
            out.toks.push(Tok {
                kind,
                text: b[start..i].iter().collect(),
                line,
                in_test: false,
            });
        } else if c.is_alphanumeric() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: b[start..i].iter().collect(),
                line,
                in_test: false,
            });
        } else {
            out.toks.push(Tok {
                kind: TokKind::Punct,
                text: c.to_string(),
                line,
                in_test: false,
            });
            i += 1;
        }
    }
    mark_test_regions(&mut out.toks);
    out
}

/// Parse a line comment as a lint pragma if it claims to be one.
fn scan_pragma(comment: &str, line: usize, out: &mut FileTokens) {
    let body = comment.trim_start_matches('/').trim();
    let Some(rest) = body.strip_prefix("lint:") else { return };
    let rest = rest.trim();
    let ok = (|| {
        let rest = rest.strip_prefix("allow(")?;
        let (rule, tail) = rest.split_once(')')?;
        let reason = tail.trim().strip_prefix(':')?.trim();
        let rule = rule.trim();
        if rule.is_empty() || reason.is_empty() {
            return None;
        }
        Some(Pragma { line, rule: rule.to_string(), reason: reason.to_string() })
    })();
    match ok {
        Some(p) => out.pragmas.push(p),
        None => out.malformed_pragmas.push(line),
    }
}

/// Length of a raw-string opener at `b[0]` (`r"`, `r#"`, …), if any.
fn raw_string_len(b: &[char]) -> Option<usize> {
    if b.first() != Some(&'r') {
        return None;
    }
    let mut i = 1;
    while i < b.len() && b[i] == '#' {
        i += 1;
    }
    (b.get(i) == Some(&'"')).then_some(i + 1)
}

/// Scan a string literal starting at `b[0]` (plain `"…"` or raw form).
/// Returns (verbatim text, newline count inside it).
fn scan_string(b: &[char]) -> (String, usize) {
    let mut lines = 0;
    if let Some(open) = raw_string_len(b) {
        let hashes = open - 2; // r + hashes + quote
        let mut i = open;
        while i < b.len() {
            if b[i] == '\n' {
                lines += 1;
            }
            if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&c| c == '#').count() == hashes
            {
                i += 1 + hashes;
                return (b[..i].iter().collect(), lines);
            }
            i += 1;
        }
        return (b.iter().collect(), lines);
    }
    let mut i = 1;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                lines += 1;
                i += 1;
            }
            '"' => return (b[..=i].iter().collect(), lines),
            _ => i += 1,
        }
    }
    (b.iter().collect(), lines)
}

/// Scan a char literal starting at `b[0] == '\''`.
fn scan_char(b: &[char]) -> String {
    let mut i = 1;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\'' => return b[..=i].iter().collect(),
            _ => i += 1,
        }
    }
    b.iter().collect()
}

/// Is `b[0] == '\''` a lifetime rather than a char literal? A lifetime is
/// `'ident` NOT followed by a closing quote (`'a'` is a char).
fn is_lifetime(b: &[char]) -> bool {
    if b.len() < 2 || !(b[1].is_alphabetic() || b[1] == '_') {
        return false;
    }
    let mut i = 2;
    while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
        i += 1;
    }
    b.get(i) != Some(&'\'')
}

/// Mark every token inside a `#[cfg(test)] mod … { … }` body. The pattern
/// is matched at token level: `#` `[` `cfg` `(` `test` `)` `]` then
/// (skipping further attributes) `mod` `<name>` `{`, and the body extends
/// to the matching close brace.
fn mark_test_regions(toks: &mut [Tok]) {
    let mut i = 0;
    while i < toks.len() {
        if is_cfg_test_attr(toks, i) {
            // skip the attribute (7 tokens), then any further #[…]
            let mut j = i + 7;
            while j < toks.len() && toks[j].text == "#" {
                j = skip_group(toks, j + 1, "[", "]");
            }
            if j < toks.len() && toks[j].text == "mod" {
                // mod name {  — find the open brace
                let mut k = j + 1;
                while k < toks.len() && toks[k].text != "{" && toks[k].text != ";" {
                    k += 1;
                }
                if k < toks.len() && toks[k].text == "{" {
                    let end = skip_group(toks, k, "{", "}");
                    for t in &mut toks[k..end.min(toks.len())] {
                        t.in_test = true;
                    }
                    i = end;
                    continue;
                }
            }
        }
        i += 1;
    }
}

/// Does `#` `[` `cfg` `(` `test` `)` `]` start at token `i`?
fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    const PAT: [&str; 7] = ["#", "[", "cfg", "(", "test", ")", "]"];
    toks.len() >= i + PAT.len() && PAT.iter().enumerate().all(|(k, p)| toks[i + k].text == *p)
}

/// Given `toks[start].text == open`, return the index one past the matching
/// `close` (or `toks.len()` if unbalanced).
pub fn skip_group(toks: &[Tok], start: usize, open: &str, close: &str) -> usize {
    debug_assert_eq!(toks[start].text, open);
    let mut depth = 0usize;
    let mut i = start;
    while i < toks.len() {
        if toks[i].text == open {
            depth += 1;
        } else if toks[i].text == close {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    toks.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.clone())
            .collect()
    }

    #[test]
    fn basic_shapes() {
        let f = tokenize("let x: u64 = sim_seed ^ 0xBEEF; // plain comment");
        let texts: Vec<&str> = f.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["let", "x", ":", "u64", "=", "sim_seed", "^", "0xBEEF", ";"]);
        assert_eq!(f.toks[7].kind, TokKind::Int);
        assert!(f.pragmas.is_empty());
    }

    #[test]
    fn strings_and_chars_are_opaque() {
        let f = tokenize("let s = \"Instant::now HashMap\"; let c = 'x'; let l: &'a str;");
        assert!(!f.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "HashMap"));
        assert!(f.toks.iter().any(|t| t.kind == TokKind::Char && t.text == "'x'"));
        assert!(f.toks.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "'a"));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let f = tokenize(r##"let a = r#"quote " inside"#; let b = "esc\"aped";"##);
        let strs: Vec<&str> = f
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs.len(), 2, "{strs:?}");
    }

    #[test]
    fn line_numbers_track_comments_and_strings() {
        let f = tokenize("a\n/* two\nlines */ b\n\"s\ntr\" c");
        let find = |name: &str| f.toks.iter().find(|t| t.text == name).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("b"), 3);
        assert_eq!(find("c"), 5);
    }

    #[test]
    fn pragma_parses_and_covers_two_lines() {
        let src = "// lint: allow(wall-clock): bench timing is the product\nInstant::now();\n";
        let f = tokenize(src);
        assert_eq!(f.pragmas.len(), 1);
        assert_eq!(f.pragmas[0].rule, "wall-clock");
        assert!(f.allows("wall-clock", 1) && f.allows("wall-clock", 2));
        assert!(!f.allows("wall-clock", 3));
        assert!(!f.allows("hash-order", 2));
    }

    #[test]
    fn malformed_pragma_is_recorded() {
        for bad in [
            "// lint: allow(wall-clock)",      // missing reason
            "// lint: allow(wall-clock):",     // empty reason
            "// lint: allow wall-clock: why",  // missing parens
        ] {
            let f = tokenize(bad);
            assert_eq!(f.malformed_pragmas, vec![1], "{bad:?}");
            assert!(f.pragmas.is_empty(), "{bad:?}");
        }
        // non-pragma comments are neither
        let f = tokenize("// lintish comment: allow nothing");
        assert!(f.pragmas.is_empty() && f.malformed_pragmas.is_empty());
    }

    #[test]
    fn cfg_test_mod_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { helper(); }\n}\nfn after() {}";
        let f = tokenize(src);
        let t = |name: &str| f.toks.iter().find(|t| t.text == name).unwrap();
        assert!(!t("live").in_test);
        assert!(t("helper").in_test);
        assert!(!t("after").in_test);
    }

    #[test]
    fn numeric_suffixes_classify() {
        let f = tokenize("0.0f32 1_000u64 0xBAD5_EED5 2.5e-3 1f64");
        let kinds: Vec<TokKind> = f.toks.iter().map(|t| t.kind).collect();
        assert_eq!(
            kinds,
            vec![TokKind::Float, TokKind::Int, TokKind::Int, TokKind::Float, TokKind::Float]
        );
    }

    #[test]
    fn underscore_is_an_ident() {
        assert_eq!(idents("match x { _ => {} }"), vec!["match", "x", "_"]);
    }
}
