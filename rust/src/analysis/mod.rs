//! `fedspace lint` — a repo-specific static-analysis pass over the Rust
//! sources (ADR-0011).
//!
//! The determinism contract (ADR-0002) promises that all three engine
//! modes produce bit-identical traces. The differential test grid checks
//! that promise *after the fact* on the scenarios it samples; this module
//! checks the *causes* up front: wall-clock reads, hash-ordered
//! containers, unnamed RNG stream derivations, unfolded `RunEvent`
//! variants, order-sensitive f32 reductions, and `SectionSpec` impls
//! missing from the round-trip registry. See [`rules`] for the registry
//! and [`tokens`] for the scanner.
//!
//! Deliberately token-level, not a parser: every rule here keys off flat
//! token shapes (`Instant :: now`, `seed ^ <lit>`, `impl X {`), so a
//! ~400-line tokenizer with exact line numbers is sufficient, has no
//! grammar to chase across Rust editions, and cannot mis-parse its way
//! into silence — the failure mode of a homegrown parser. The trade-off
//! (no type or name resolution) is acceptable because the rules target
//! idioms this repo bans outright rather than semantic properties.
//!
//! Suppression is explicit and audited: `// lint: allow(<rule>): <reason>`
//! on the violating line or the line above. Malformed pragmas and
//! pragmas naming unknown rules are themselves findings, and the JSON
//! report counts suppressions so CI can pin the number.

pub mod rules;
pub mod tokens;

pub use rules::{check_all, Emitter, FileScan, Finding, RULES};

use anyhow::{Context, Result};
use std::fs;
use std::path::Path;

/// Schema tag of the JSON lint report.
pub const LINT_SCHEMA: &str = "fedspace-lint-v1";

/// Outcome of one lint run: findings plus enough context to render the
/// text and `fedspace-lint-v1` JSON reports.
#[derive(Debug)]
pub struct LintReport {
    /// Scan root as given (display only).
    pub root: String,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Live findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings suppressed by pragmas.
    pub suppressed: usize,
}

impl LintReport {
    /// No findings survived?
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report: one `file:line: rule: message` per finding
    /// plus a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: {}: {}\n", f.file, f.line, f.rule, f.message));
        }
        out.push_str(&format!(
            "lint: {} file(s), {} finding(s), {} suppressed by pragma\n",
            self.files,
            self.findings.len(),
            self.suppressed
        ));
        out
    }

    /// The `fedspace-lint-v1` JSON document.
    pub fn to_json(&self) -> String {
        use crate::sim::events::json_escape;
        let mut s = String::with_capacity(1024);
        s.push_str(&format!(
            "{{\"schema\":\"{}\",\"root\":\"{}\",\"files\":{},\"suppressed\":{},\"clean\":{},",
            LINT_SCHEMA,
            json_escape(&self.root),
            self.files,
            self.suppressed,
            self.clean()
        ));
        s.push_str("\"rules\":[");
        for (k, (id, summary)) in RULES.iter().enumerate() {
            if k > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"id\":\"{}\",\"summary\":\"{}\"}}",
                json_escape(id),
                json_escape(summary)
            ));
        }
        s.push_str("],\"findings\":[");
        for (k, f) in self.findings.iter().enumerate() {
            if k > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
                json_escape(f.rule),
                json_escape(&f.file),
                f.line,
                json_escape(&f.message)
            ));
        }
        s.push_str("]}");
        s
    }
}

/// Lint in-memory sources: `(rel_path, source)` pairs. The pure core —
/// the fixture tests and the CLI both end up here.
pub fn lint_sources(root: &str, sources: &[(String, String)]) -> LintReport {
    let scans: Vec<FileScan> = sources
        .iter()
        .map(|(rel, src)| FileScan { rel: rel.clone(), tokens: tokens::tokenize(src) })
        .collect();
    let em = check_all(&scans);
    LintReport {
        root: root.to_string(),
        files: scans.len(),
        findings: em.findings,
        suppressed: em.suppressed,
    }
}

/// Lint every `.rs` file under `root` (recursively, sorted traversal so
/// reports are byte-stable run to run).
pub fn lint_dir(root: &Path) -> Result<LintReport> {
    let mut rels = Vec::new();
    collect_rs(root, Path::new(""), &mut rels)
        .with_context(|| format!("scanning {}", root.display()))?;
    rels.sort();
    let mut sources = Vec::with_capacity(rels.len());
    for rel in rels {
        let full = root.join(&rel);
        let src = fs::read_to_string(&full)
            .with_context(|| format!("reading {}", full.display()))?;
        sources.push((rel, src));
    }
    Ok(lint_sources(&root.display().to_string(), &sources))
}

/// Accumulate `/`-separated relative paths of `.rs` files under
/// `root/rel`.
fn collect_rs(root: &Path, rel: &Path, out: &mut Vec<String>) -> Result<()> {
    let dir = root.join(rel);
    for entry in fs::read_dir(&dir).with_context(|| format!("listing {}", dir.display()))? {
        let entry = entry?;
        let name = entry.file_name();
        let sub = rel.join(&name);
        let ty = entry.file_type()?;
        if ty.is_dir() {
            collect_rs(root, &sub, out)?;
        } else if ty.is_file() && name.to_string_lossy().ends_with(".rs") {
            let rel_str = sub
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel_str);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_report::{parse_json, Json};

    #[test]
    fn report_json_round_trips_through_in_repo_parser() {
        let src = "let t = Instant::now(); // a \"quoted\" site".to_string();
        let report = lint_sources("mem", &[("app/x.rs".to_string(), src)]);
        assert_eq!(report.findings.len(), 1);
        let doc = parse_json(&report.to_json()).expect("lint JSON parses");
        let Json::Obj(fields) = &doc else { panic!("object") };
        let get = |k: &str| fields.iter().find(|(n, _)| n == k).map(|(_, v)| v);
        assert_eq!(get("schema"), Some(&Json::Str(LINT_SCHEMA.to_string())));
        assert_eq!(get("clean"), Some(&Json::Bool(false)));
        let Some(Json::Arr(fs)) = get("findings") else { panic!("findings array") };
        assert_eq!(fs.len(), 1);
        let Json::Obj(f0) = &fs[0] else { panic!("finding object") };
        assert!(f0.contains(&("rule".to_string(), Json::Str("wall-clock".to_string()))));
        assert!(f0.contains(&("line".to_string(), Json::Num(1.0))));
        let Some(Json::Arr(rules)) = get("rules") else { panic!("rules array") };
        assert_eq!(rules.len(), RULES.len());
    }

    #[test]
    fn clean_report_renders_summary_only() {
        let report = lint_sources("mem", &[("app/x.rs".to_string(), "fn main() {}".to_string())]);
        assert!(report.clean());
        let text = report.render_text();
        assert_eq!(text.lines().count(), 1);
        assert!(text.contains("0 finding(s)"));
    }
}
