//! Performance micro-benches (deliverable e): the hot paths of all three
//! layers as exercised from the coordinator, with before/after history in
//! EXPERIMENTS.md §Perf.
//!
//! L1/L2 (through PJRT artifacts — requires the `pjrt` feature and
//! `make artifacts`; skipped gracefully otherwise):
//!   local_train, grad_eval, eval_batch, aggregate_chunk
//! L3 (pure Rust):
//!   CPU aggregation oracle (blocked vs streamed through `w` per entry),
//!   scheduler forecast + random search (parallel vs the serial reference),
//!   connectivity computation (optimized parallel vs the trig-heavy serial
//!   reference), RF fit/predict, synthetic-image materialization.

use fedspace::bench_report;
use fedspace::bench_util::{bench, section};
use fedspace::connectivity::{ConnectivityParams, ConnectivitySchedule, ConnectivityStream};
use fedspace::data::{Dataset, SynthConfig};
use fedspace::exec;
use fedspace::fl::server::{CpuAggregator, ServerAggregator};
use fedspace::fl::GradientEntry;
use fedspace::ml::{RandomForest, RandomForestParams, Regressor};
use fedspace::orbit::{planet_ground_stations, planet_labs_like};
use fedspace::rng::Rng;
use fedspace::runtime::ModelRuntime;
use fedspace::sched::{
    random_search, random_search_serial, SatForecastState, SearchParams, UtilityModel,
};

/// fmow-sized flat parameter dimension, used when the PJRT runtime (which
/// would report the exact meta.d) is unavailable.
const D_FMOW: usize = 588_000;

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
}

fn bench_pjrt(rt: &ModelRuntime, rng: &mut Rng) -> anyhow::Result<()> {
    let m = rt.meta.clone();
    let w = rt.init_params(rng);
    let n = m.e_steps * m.batch;
    let xs = rand_vec(rng, n * m.img_dim, 1.0);
    let ys: Vec<f32> = (0..n).map(|_| rng.gen_range(0, 62) as f32).collect();
    let s = bench("local_train (E=4, B=32)", 1, 10, || {
        let _ = rt.local_train(&w, &xs, &ys, 0.5).unwrap();
    });
    println!("    -> {:.1} local updates/s", s.throughput(1.0));
    let xe = rand_vec(rng, m.eval_batch * m.img_dim, 1.0);
    let ye: Vec<f32> = (0..m.eval_batch).map(|_| rng.gen_range(0, 62) as f32).collect();
    bench("eval_batch (B=64)", 1, 10, || {
        let _ = rt.eval_batch(&w, &xe, &ye).unwrap();
    });
    let x1 = rand_vec(rng, m.batch * m.img_dim, 1.0);
    let y1: Vec<f32> = (0..m.batch).map(|_| rng.gen_range(0, 62) as f32).collect();
    bench("grad_eval (B=32)", 1, 10, || {
        let _ = rt.grad_eval(&w, &x1, &y1).unwrap();
    });
    let g = rand_vec(rng, m.chunk * m.d, 0.01);
    let wt = vec![1.0 / m.chunk as f32; m.chunk];
    let s = bench("aggregate_chunk (CH=16, Pallas)", 1, 10, || {
        let _ = rt.aggregate_chunk_raw(&w, &g, &wt).unwrap();
    });
    let bytes = (m.chunk * m.d + 2 * m.d) as f64 * 4.0;
    println!("    -> {:.2} GB/s effective", bytes / s.median_s / 1e9);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0);
    println!("threads: {}", exec::global_pool().size());

    section("L1/L2: PJRT artifacts (size = fmow, d = 588k)");
    let d = match ModelRuntime::load("artifacts", "fmow") {
        Ok(rt) => {
            bench_pjrt(&rt, &mut rng)?;
            rt.meta.d
        }
        Err(e) => {
            println!("  skipped ({e:#})");
            D_FMOW
        }
    };

    section("L3: GS aggregation oracle (pure Rust, blocked accumulate)");
    let w = rand_vec(&mut rng, d, 0.1);
    let entries: Vec<GradientEntry> = (0..16)
        .map(|sat| GradientEntry {
            sat,
            staleness: sat % 5,
            grad: rand_vec(&mut rng, d, 0.01).into(),
            n_samples: 1,
        })
        .collect();
    let s = bench("CpuAggregator 16 gradients", 1, 10, || {
        let mut wc = w.clone();
        CpuAggregator.aggregate(&mut wc, &entries, 0.5).unwrap();
    });
    let bytes = (entries.len() * d + 2 * d) as f64 * 4.0;
    println!("    -> {:.2} GB/s effective", bytes / s.median_s / 1e9);
    bench_report::record("cpu_aggregate_16", s.median_s);

    section("L3: FedSpace scheduler (Eq. 13 random search)");
    let constellation = planet_labs_like(191, 0);
    let stations = planet_ground_stations();
    let sched =
        ConnectivitySchedule::compute(&constellation, &stations, 96, ConnectivityParams::default());
    let states = vec![SatForecastState::fresh(); 191];
    let u = UtilityModel::new("forest")?;
    for n_search in [500usize, 5000] {
        let params = SearchParams { i0: 24, n_min: 4, n_max: 8, n_search };
        let mut srng = Rng::new(1);
        let before =
            bench(&format!("random_search |R|={n_search} serial (reference)"), 1, 5, || {
                let _ = random_search_serial(&sched, 0, &states, &u, 1.0, &params, &mut srng);
            });
        let mut prng = Rng::new(1);
        let after = bench(&format!("random_search |R|={n_search} parallel"), 1, 5, || {
            let _ = random_search(&sched, 0, &states, &u, 1.0, &params, &mut prng);
        });
        println!(
            "    -> {:.0} candidates/s, {:.2}x vs serial",
            after.throughput(n_search as f64),
            before.median_s / after.median_s
        );
        bench_report::record(&format!("search_serial_{n_search}"), before.median_s);
        bench_report::record(&format!("search_parallel_{n_search}"), after.median_s);
    }

    section("L3: orbital mechanics (connectivity schedule C)");
    let params = ConnectivityParams::default();
    let before = bench("compute C reference: 191 sats x 96 slots x 12 GS", 1, 5, || {
        let _ = ConnectivitySchedule::compute_reference(
            &constellation,
            &stations,
            96,
            params.clone(),
        );
    });
    let after = bench("compute C optimized: 191 sats x 96 slots x 12 GS", 1, 5, || {
        let _ = ConnectivitySchedule::compute(&constellation, &stations, 96, params.clone());
    });
    println!("    -> {:.2}x vs reference", before.median_s / after.median_s);
    bench_report::record("connectivity_compute_reference", before.median_s);
    bench_report::record("connectivity_compute_optimized", after.median_s);

    section("L3: streamed connectivity (chunked, recyclable, ADR-0004)");
    // whole-horizon generation through the stream vs the all-at-once
    // compute above — same pipeline, so overhead is chunk bookkeeping only
    let stream = ConnectivityStream::new(
        &constellation,
        &stations,
        96,
        ConnectivityParams::default(),
        ConnectivityStream::DEFAULT_CHUNK_LEN / 4,
    );
    let streamed = bench("stream C chunked: 191 sats x 96 slots (24/chunk)", 1, 5, || {
        let mut chunk = fedspace::connectivity::ScheduleChunk::default();
        for c in 0..stream.n_chunks() {
            stream.fill_chunk(c, &mut chunk);
        }
    });
    println!("    -> {:.2}x vs all-at-once", after.median_s / streamed.median_s);
    bench_report::record("connectivity_stream_chunked", streamed.median_s);
    // one chunk of a mega-fleet: the unit of work the streamed engine pays
    // per chunk boundary on a 4408-satellite scenario
    let mega = fedspace::orbit::Constellation::walker(&fedspace::orbit::WalkerSpec {
        pattern: fedspace::orbit::WalkerPattern::Delta,
        n_sats: 1584,
        planes: 72,
        phasing: 17,
        alt_m: 550e3,
        inc_deg: 53.0,
    });
    let mega_stream = ConnectivityStream::new(
        &mega,
        &stations,
        ConnectivityStream::DEFAULT_CHUNK_LEN,
        ConnectivityParams::default(),
        ConnectivityStream::DEFAULT_CHUNK_LEN,
    );
    let s = bench("stream one chunk: 1584 sats x 96 slots", 1, 3, || {
        let mut chunk = fedspace::connectivity::ScheduleChunk::default();
        mega_stream.fill_chunk(0, &mut chunk);
    });
    bench_report::record("connectivity_stream_mega_chunk", s.median_s);
    // the same chunk with pass durations recorded (ADR-0008): the extra
    // cost a byte-budgeted run pays to know each contact's capacity
    let mega_timed = ConnectivityStream::new(
        &mega,
        &stations,
        ConnectivityStream::DEFAULT_CHUNK_LEN,
        ConnectivityParams::default(),
        ConnectivityStream::DEFAULT_CHUNK_LEN,
    )
    .with_durations();
    let timed = bench("timed chunk: 1584 sats x 96 slots (durations on)", 1, 3, || {
        let mut chunk = fedspace::connectivity::ScheduleChunk::default();
        mega_timed.fill_chunk(0, &mut chunk);
    });
    println!("    -> {:.2}x the untimed chunk", timed.median_s / s.median_s);
    bench_report::record("contact_capacity_route", timed.median_s);

    section("L3: ISL routing (per-step BFS over the contact graph, ADR-0005)");
    // the whole-horizon routing cost the dense/contact-list modes pay once
    // per scenario — and, divided by n_chunks, what each streamed chunk pays
    let isl_sc = fedspace::cfg::Scenario::builtin("isl-iridium-66").expect("builtin");
    let (isl_c, isl_sched) = isl_sc.build_schedule();
    let topo = isl_sc.build_isl(&isl_c).expect("isl scenario");
    let s = bench("route 66 sats x 480 steps (+grid, max 3 hops)", 1, 5, || {
        let _ = fedspace::connectivity::ContactGraph::build(&topo, &isl_sched);
    });
    bench_report::record("isl_route_iridium_480", s.median_s);

    section("L3: federation reconcile (multi-gateway model merge, ADR-0006)");
    // one federated "round" at fmow model scale: four gateways each
    // receive + aggregate one gradient, then the periodic cadence merges
    // the four replicas (activity-weighted, gateway-index order) — the
    // cross-gateway hot path a multi-gateway run pays per reconcile
    {
        use fedspace::fl::{Federation, FederationSpec, ReconcilePolicy};
        let fd = 262_144usize;
        let spec = FederationSpec::split(
            &["a", "b", "c", "d"],
            &[0, 1, 2, 3],
            ReconcilePolicy::Periodic { every: 1 },
        );
        let grads: Vec<Vec<f32>> = (0..4).map(|_| rand_vec(&mut rng, fd, 0.01)).collect();
        let mut fed = Federation::new(&spec, vec![0.0f32; fd], 0.5);
        let mut agg = CpuAggregator;
        let s = bench("federated round: 4 gateways x 256k params + merge", 1, 10, || {
            for (g, grad) in grads.iter().enumerate() {
                fed.receive(g, g, grad.clone(), fed.round(), 1);
                fed.update(g, &mut agg).unwrap();
            }
            fed.end_of_step(0); // every = 1 -> reconcile fires
        });
        let bytes = (4 * fd * 3) as f64 * 4.0; // 4 aggregates + 4-way merge
        println!("    -> {:.2} GB/s effective", bytes / s.median_s / 1e9);
        bench_report::record("federation_reconcile", s.median_s);
    }

    section("L3: robust aggregation (Byzantine-tolerant Eq. 4, ADR-0007)");
    // one buffer flush at mega-constellation streamed scale: 48 gradients
    // of 256k params — the dense mean is the reference the per-coordinate
    // order statistics are measured against
    {
        use fedspace::fl::{CoordinateMedian, MultiKrum, TrimmedMean};
        let rd = 262_144usize;
        let rw = rand_vec(&mut rng, rd, 0.1);
        let rentries: Vec<GradientEntry> = (0..48)
            .map(|sat| GradientEntry {
                sat,
                staleness: sat % 5,
                grad: rand_vec(&mut rng, rd, 0.01).into(),
                n_samples: 1,
            })
            .collect();
        let mean = bench("mean 48 x 256k (reference)", 1, 5, || {
            let mut wc = rw.clone();
            CpuAggregator.aggregate(&mut wc, &rentries, 0.5).unwrap();
        });
        bench_report::record("robust_aggregate_mean", mean.median_s);
        let med = bench("coordinate-median 48 x 256k", 1, 5, || {
            let mut wc = rw.clone();
            CoordinateMedian.aggregate(&mut wc, &rentries, 0.5).unwrap();
        });
        println!("    -> {:.2}x the mean's cost", med.median_s / mean.median_s);
        bench_report::record("robust_aggregate_median", med.median_s);
        let tm = bench("trimmed-mean (trim=0.1) 48 x 256k", 1, 5, || {
            let mut wc = rw.clone();
            TrimmedMean { trim: 0.1 }.aggregate(&mut wc, &rentries, 0.5).unwrap();
        });
        println!("    -> {:.2}x the mean's cost", tm.median_s / mean.median_s);
        bench_report::record("robust_aggregate_trimmed", tm.median_s);
        let mk = bench("multi-krum (f=5) 48 x 256k", 1, 5, || {
            let mut wc = rw.clone();
            MultiKrum { f: 5, m: 0 }.aggregate(&mut wc, &rentries, 0.5).unwrap();
        });
        println!("    -> {:.2}x the mean's cost", mk.median_s / mean.median_s);
        bench_report::record("robust_aggregate_krum", mk.median_s);
    }

    section("L3: sparse aggregation (top-k wire form, ADR-0008)");
    // one buffer flush at the walker-starlink-4408 streamed scale, dense
    // vs the top-k 1% sparse wire form the compression scenarios ship —
    // the sparse path touches 48 x 2.6k coordinates instead of 48 x 256k
    {
        use fedspace::fl::{CodecKind, LinkSpec, UpdateCodec};
        let rd = 262_144usize;
        let rw = rand_vec(&mut rng, rd, 0.1);
        let dense_entries: Vec<GradientEntry> = (0..48)
            .map(|sat| GradientEntry {
                sat,
                staleness: sat % 5,
                grad: rand_vec(&mut rng, rd, 0.01).into(),
                n_samples: 1,
            })
            .collect();
        let spec = LinkSpec { codec: CodecKind::TopK, topk_frac: 0.01, ..Default::default() };
        let mut codec = UpdateCodec::new(&spec, 7);
        let sparse_entries: Vec<GradientEntry> = dense_entries
            .iter()
            .map(|e| GradientEntry {
                sat: e.sat,
                staleness: e.staleness,
                grad: codec.encode(e.grad.to_dense(), &mut Vec::new()),
                n_samples: e.n_samples,
            })
            .collect();
        let dense_s = bench("dense aggregate 48 x 256k (reference)", 1, 5, || {
            let mut wc = rw.clone();
            CpuAggregator.aggregate(&mut wc, &dense_entries, 0.5).unwrap();
        });
        let sparse_s = bench("sparse aggregate 48 x top-k 1% of 256k", 1, 5, || {
            let mut wc = rw.clone();
            CpuAggregator.aggregate(&mut wc, &sparse_entries, 0.5).unwrap();
        });
        println!("    -> {:.2}x vs dense", dense_s.median_s / sparse_s.median_s);
        bench_report::record("sparse_aggregate_dense_ref", dense_s.median_s);
        bench_report::record("sparse_aggregate_topk", sparse_s.median_s);
    }

    section("L3: event-stream observer overhead (ADR-0009)");
    // the same mock run with event recording off (NullSink fast path — what
    // every normal run pays) vs on (every event cloned into the log); the
    // tracked median is the recording-on run, the printout shows the ratio
    {
        use fedspace::app::run_mock_experiment;
        use fedspace::cfg::{AlgorithmKind, Scenario};
        let sc = Scenario::builtin("paper-fig7")
            .expect("builtin registry")
            .scaled(Some(24), Some(192));
        let mut cfg = sc.experiment_config(AlgorithmKind::FedBuff);
        cfg.events.record = false;
        let off = bench("engine run, events off (NullSink)", 1, 5, || {
            let _ = run_mock_experiment(&cfg, None).unwrap();
        });
        cfg.events.record = true;
        let on = bench("engine run, events recorded", 1, 5, || {
            let _ = run_mock_experiment(&cfg, None).unwrap();
        });
        println!(
            "    -> recording costs {:+.1}% over the null path",
            100.0 * (on.median_s / off.median_s - 1.0)
        );
        bench_report::record("event_sink_overhead", on.median_s);
    }

    section("L3: serving front end (bounded ingest + batched drain, ADR-0010)");
    // steady-state serving cost: offers fanned over four bounded gateway
    // queues with periodic batched drains, then flushed to empty — the
    // uploads/sec ceiling the loadgen replay measures end to end
    {
        use fedspace::fl::{
            FederationSpec, Offer, PendingUpload, ReconcilePolicy, ServeCore, ServeSpec,
        };
        use fedspace::sim::NullSink;
        let mut sink = NullSink;
        let sd = 4096usize;
        let spec = FederationSpec::split(
            &["a", "b", "c", "d"],
            &[0, 1, 2, 3],
            ReconcilePolicy::Periodic { every: 4 },
        );
        let sspec = ServeSpec { queue_cap: 4096, batch: 256, shards: 0 };
        let grads: Vec<Vec<f32>> = (0..256).map(|_| rand_vec(&mut rng, sd, 0.01)).collect();
        let n_offers = 1024usize;
        let s = bench("ingest+drain 1024 uploads x 4k params, 4 gateways", 1, 5, || {
            let mut serve = ServeCore::new(&spec, &sspec, vec![0.0f32; sd], 0.5);
            let mut agg = CpuAggregator;
            for j in 0..n_offers {
                let up = PendingUpload {
                    sat: j % 64,
                    grad: grads[j % grads.len()].clone().into(),
                    base_round: serve.core().round(),
                    n_samples: 1,
                };
                let _ = serve.offer(j % 4, up);
                if j % 256 == 255 {
                    serve.drain(&mut agg, &mut sink).unwrap();
                }
            }
            while (0..4).any(|g| serve.queue_depth(g) > 0) {
                serve.drain(&mut agg, &mut sink).unwrap();
            }
        });
        println!("    -> {:.0} uploads/s sustained", s.throughput(n_offers as f64));
        bench_report::record("serve_ingest_throughput", s.median_s);
        // one drain tick that aggregates a gradient per gateway and crosses
        // the reconcile cadence at fmow-chunk model scale — the p99-shaped
        // unit of latency the loadgen percentiles are made of
        let fd = 262_144usize;
        let every1 = FederationSpec::split(
            &["a", "b", "c", "d"],
            &[0, 1, 2, 3],
            ReconcilePolicy::Periodic { every: 1 },
        );
        let big: Vec<Vec<f32>> = (0..4).map(|_| rand_vec(&mut rng, fd, 0.01)).collect();
        let s = bench("drain tick: 4 gateways x 256k params + reconcile", 1, 5, || {
            let mut serve = ServeCore::new(
                &every1,
                &ServeSpec { queue_cap: 16, batch: 4, shards: 0 },
                vec![0.0f32; fd],
                0.5,
            );
            let mut agg = CpuAggregator;
            for (g, grad) in big.iter().enumerate() {
                let up = PendingUpload {
                    sat: g,
                    grad: grad.clone().into(),
                    base_round: 0,
                    n_samples: 1,
                };
                assert!(matches!(serve.offer(g, up), Offer::Accepted));
            }
            serve.drain(&mut agg, &mut sink).unwrap();
        });
        bench_report::record("serve_reconcile_latency", s.median_s);
    }

    section("L3: utility regressor (random forest)");
    let x: Vec<Vec<f64>> = (0..400)
        .map(|_| (0..10).map(|_| rng.gen_f64(-1.0, 1.0)).collect())
        .collect();
    let y: Vec<f64> = x.iter().map(|r| r[0] * r[0] - r[1]).collect();
    bench("RF fit (400 x 10, 50 trees)", 1, 5, || {
        let mut rf = RandomForest::new(RandomForestParams::default());
        rf.fit(&x, &y);
    });
    let mut rf = RandomForest::new(RandomForestParams::default());
    rf.fit(&x, &y);
    bench("RF predict x1000", 2, 10, || {
        for row in x.iter().take(1000.min(x.len())) {
            let _ = rf.predict(row);
        }
    });

    section("L3: dataset synthesis");
    let ds = Dataset::generate(SynthConfig { n_train: 1000, n_val: 16, ..Default::default() });
    let idx: Vec<usize> = (0..128).collect();
    let s = bench("materialize batch of 128 images", 1, 10, || {
        let _ = ds.make_batch(&ds.train, &idx);
    });
    println!("    -> {:.0} images/s", s.throughput(128.0));

    if let Some(path) = bench_report::flush_to_env_path()? {
        println!("\nmachine-readable results written to {path}");
    }
    Ok(())
}
