//! Figure 6 + Table 2 — training curves and time-to-target-accuracy for
//! Sync / Async / FedBuff / FedSpace over IID and Non-IID partitions.
//!
//! Default: the fast analytic mock backend (paper-shaped dynamics, runs in
//! seconds) at constellation scale. Set FEDSPACE_BENCH_PJRT=1 to run the
//! full three-layer PJRT path instead (minutes; the EXPERIMENTS.md record
//! was produced that way). Curves land in results/fig6_*.csv.

use fedspace::app::{run_mock_experiment, run_pjrt_experiment, ExperimentOutput};
use fedspace::bench_util::section;
use fedspace::cfg::{AlgorithmKind, DataDist, ExperimentConfig};
use fedspace::metrics::{write_file, Table};

const ALGOS: [AlgorithmKind; 4] = [
    AlgorithmKind::Sync,
    AlgorithmKind::Async,
    AlgorithmKind::FedBuff,
    AlgorithmKind::FedSpace,
];

fn pjrt_mode() -> bool {
    std::env::var("FEDSPACE_BENCH_PJRT").map_or(false, |v| v == "1")
}

fn config(alg: AlgorithmKind, dist: DataDist, pjrt: bool) -> ExperimentConfig {
    if pjrt {
        ExperimentConfig {
            algorithm: alg,
            dist,
            n_sats: 48,
            n_steps: 192, // 2 simulated days
            n_train: 4_800,
            n_val: 512,
            fedbuff_m: 24,
            i0: 24,
            n_min: 1,
            n_max: 6,
            n_search: 1000,
            utility_samples: 150,
            eval_every: 8,
            ..Default::default()
        }
    } else {
        ExperimentConfig {
            algorithm: alg,
            dist,
            n_sats: 96,
            n_steps: 480,
            fedbuff_m: 48,
            i0: 24,
            n_min: 1,
            n_max: 4,
            n_search: 500,
            utility_samples: 200,
            eval_every: 4,
            ..Default::default()
        }
    }
}

fn target(pjrt: bool) -> f64 {
    // mock "accuracy" is distance-to-optimum; PJRT is top-1 on 62 classes.
    if pjrt {
        0.40
    } else {
        0.90
    }
}

fn run(alg: AlgorithmKind, dist: DataDist) -> anyhow::Result<ExperimentOutput> {
    let pjrt = pjrt_mode();
    let cfg = config(alg, dist, pjrt);
    if pjrt {
        run_pjrt_experiment(&cfg, 512, None)
    } else {
        run_mock_experiment(&cfg, None)
    }
}

fn main() -> anyhow::Result<()> {
    let pjrt = pjrt_mode();
    let tgt = target(pjrt);
    section(&format!(
        "Figure 6 + Table 2 ({} backend, target accuracy {:.0}%)",
        if pjrt { "PJRT three-layer" } else { "analytic mock" },
        tgt * 100.0
    ));

    for dist in [DataDist::Iid, DataDist::NonIid] {
        println!("\n--- {dist:?} ---");
        let mut rows: Vec<(AlgorithmKind, Option<f64>, f64)> = Vec::new();
        for alg in ALGOS {
            let t0 = std::time::Instant::now();
            let out = run(alg, dist)?;
            let r = &out.result;
            let days = r.trace.curve.days_to_accuracy(tgt);
            println!(
                "{:>9}: best_acc={:.3} rounds={} idle={:.0}% days_to_target={} ({:.1}s wall)",
                alg.name(),
                r.trace.curve.best_accuracy(),
                r.final_round,
                100.0 * r.trace.idle_fraction(),
                days.map_or("-".into(), |d| format!("{d:.2}")),
                t0.elapsed().as_secs_f64(),
            );
            write_file(
                &format!("results/fig6_{}_{:?}.csv", alg.name(), dist),
                &r.trace.curve.to_csv(),
            )?;
            rows.push((alg, days, r.trace.curve.best_accuracy()));
        }
        // Table 2 for this distribution
        let fs_days = rows
            .iter()
            .find(|(a, _, _)| *a == AlgorithmKind::FedSpace)
            .and_then(|(_, d, _)| *d);
        let mut t = Table::new(&["scheme", "days", "gain vs fedspace", "best acc"]);
        for (alg, days, best) in &rows {
            let gain = match (days, fs_days) {
                (Some(d), Some(f)) if *alg != AlgorithmKind::FedSpace => {
                    format!("{:.1}x", d / f)
                }
                _ if *alg == AlgorithmKind::FedSpace => "n/a".into(),
                _ => "-".into(),
            };
            t.row(&[
                alg.name().to_string(),
                days.map_or("-".into(), |d| format!("{d:.2}")),
                gain,
                format!("{best:.3}"),
            ]);
        }
        println!("\nTable 2 ({dist:?}):\n{}", t.render());
    }
    println!("curves written to results/fig6_<scheme>_<dist>.csv");
    println!("paper shape: sync reaches target 13-16x slower; async never; fedspace fastest");
    Ok(())
}
