//! Figure 2 — real-world satellite connectivity statistics.
//!
//! Regenerates (a) the |C_i| time series over one day and (b) the
//! histogram of contacts per satellite n_k, for the Planet-Labs-like
//! 191-satellite / 12-station network, plus timing of the connectivity
//! computation itself. CSVs land in results/.

use fedspace::bench_util::{bench, section, time_once};
use fedspace::connectivity::{ConnectivityParams, ConnectivitySchedule, ConnectivityStats};
use fedspace::metrics::write_file;
use fedspace::orbit::{planet_ground_stations, planet_labs_like};

fn main() -> anyhow::Result<()> {
    section("Figure 2: connectivity of 191 satellites / 12 ground stations");
    let constellation = planet_labs_like(191, 0);
    let stations = planet_ground_stations();

    let (sched, _) = time_once("compute C (96 slots, T0=15min)", || {
        ConnectivitySchedule::compute(&constellation, &stations, 96, ConnectivityParams::default())
    });
    let stats = ConnectivityStats::from_schedule(&sched);

    println!("\nFig 2(a): |C_i| over one day");
    println!(
        "  min |C_i| = {}   max |C_i| = {}   (paper: 4 / 68)",
        stats.min_set, stats.max_set
    );
    let mut csv = String::from("i,n_connected\n");
    for (i, n) in stats.set_sizes.iter().enumerate() {
        csv.push_str(&format!("{i},{n}\n"));
    }
    write_file("results/fig2a_set_sizes.csv", &csv)?;

    println!("\nFig 2(b): histogram of contacts/day n_k");
    let hist = stats.contacts_histogram(1);
    let lo = stats.contacts_per_sat.iter().min().unwrap();
    let hi = stats.contacts_per_sat.iter().max().unwrap();
    println!(
        "  n_k range = [{lo}, {hi}]  mean = {:.1}   (paper: 5 .. 19)",
        stats.mean_contacts
    );
    let mut csv = String::from("n_contacts,n_satellites\n");
    for (bucket, count) in &hist {
        csv.push_str(&format!("{bucket},{count}\n"));
    }
    write_file("results/fig2b_contacts_hist.csv", &csv)?;
    println!("  wrote results/fig2a_set_sizes.csv, results/fig2b_contacts_hist.csv");

    section("perf: connectivity computation");
    bench("C 96 slots / 191 sats / 12 GS", 1, 5, || {
        let _ = ConnectivitySchedule::compute(
            &constellation,
            &stations,
            96,
            ConnectivityParams::default(),
        );
    });
    bench("C 480 slots (5-day experiment horizon)", 0, 3, || {
        let _ = ConnectivitySchedule::compute(
            &constellation,
            &stations,
            480,
            ConnectivityParams::default(),
        );
    });
    Ok(())
}
