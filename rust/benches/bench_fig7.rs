//! Figure 7 — staleness and idleness distributions of the four schemes.
//!
//! Runs each algorithm over the same constellation (mock backend by
//! default; FEDSPACE_BENCH_PJRT=1 for the full path) and prints/writes the
//! per-scheme staleness histogram and idle-connection counts.

use fedspace::app::{run_mock_experiment, run_pjrt_experiment};
use fedspace::bench_util::section;
use fedspace::cfg::{AlgorithmKind, DataDist, ExperimentConfig};
use fedspace::metrics::{write_file, Table};

fn main() -> anyhow::Result<()> {
    let pjrt = std::env::var("FEDSPACE_BENCH_PJRT").map_or(false, |v| v == "1");
    section(&format!(
        "Figure 7: staleness / idleness distribution ({} backend)",
        if pjrt { "PJRT" } else { "mock" }
    ));
    let mut csv = String::from("scheme,staleness,count\n");
    let mut t = Table::new(&["scheme", "s=0", "s=1", "s=2", "s=3", "s=4+", "idle", "idle%"]);
    for alg in [
        AlgorithmKind::Sync,
        AlgorithmKind::Async,
        AlgorithmKind::FedBuff,
        AlgorithmKind::FedSpace,
    ] {
        let cfg = ExperimentConfig {
            algorithm: alg,
            dist: DataDist::NonIid,
            n_sats: if pjrt { 48 } else { 96 },
            n_steps: if pjrt { 192 } else { 480 },
            n_train: if pjrt { 4_800 } else { 19_100 },
            n_val: 512,
            fedbuff_m: if pjrt { 24 } else { 48 },
            n_search: 500,
            utility_samples: 150,
            n_min: 1,
            n_max: if pjrt { 6 } else { 4 },
            eval_every: 16,
            ..Default::default()
        };
        let out = if pjrt {
            run_pjrt_experiment(&cfg, 256, None)?
        } else {
            run_mock_experiment(&cfg, None)?
        };
        let tr = &out.result.trace;
        let s4plus: u64 = tr
            .staleness
            .entries()
            .filter(|(s, _)| *s >= 4)
            .map(|(_, c)| c)
            .sum();
        t.row(&[
            alg.name().to_string(),
            tr.staleness.count(0).to_string(),
            tr.staleness.count(1).to_string(),
            tr.staleness.count(2).to_string(),
            tr.staleness.count(3).to_string(),
            s4plus.to_string(),
            tr.idle.to_string(),
            format!("{:.0}%", 100.0 * tr.idle_fraction()),
        ]);
        for (s, c) in tr.staleness.entries() {
            csv.push_str(&format!("{},{},{}\n", alg.name(), s, c));
        }
        csv.push_str(&format!("{},idle,{}\n", alg.name(), tr.idle));
    }
    println!("{}", t.render());
    write_file("results/fig7_staleness_idleness.csv", &csv)?;
    println!("wrote results/fig7_staleness_idleness.csv");
    println!(
        "paper shape: sync ~90% idle; async long staleness tail; fedspace small\nidle + mass at low staleness"
    );
    Ok(())
}
