//! Dense vs contact-list engine mode — the speedup the contact-list walk
//! buys when most time indexes carry no contact, plus a bit-identity check
//! so the bench can never report a fast-but-wrong mode.
//!
//! The connectivity schedule is computed once per scenario and shared, so
//! the timings isolate the engine loop itself.
//!
//! Run from `rust/`: `cargo bench --bench bench_engine_modes`

use fedspace::app::run_mock_on_schedule;
use fedspace::bench_util::{section, time_once};
use fedspace::cfg::{AlgorithmKind, EngineMode, Scenario};
use fedspace::connectivity::ConnectivitySchedule;
use fedspace::testing::assert_same_run;

fn run_modes(sc: &Scenario, sched: &ConnectivitySchedule, alg: AlgorithmKind) {
    let mut cfg = sc.experiment_config(alg);
    let mut results = Vec::new();
    let mut timings = Vec::new();
    for mode in [EngineMode::Dense, EngineMode::ContactList] {
        cfg.engine_mode = mode;
        let (out, dt) = time_once(&format!("  {} / {}", alg.name(), mode.name()), || {
            run_mock_on_schedule(&cfg, sched, None).expect("run")
        });
        results.push(out.result);
        timings.push(dt);
    }
    assert_same_run(&results[0], &results[1], alg.name());
    println!(
        "  identical traces; engine speedup {:.2}x",
        timings[0] / timings[1].max(1e-9)
    );
}

fn bench_scenario(name: &str, algorithms: &[AlgorithmKind]) {
    let sc = Scenario::builtin(name).expect("builtin");
    section(&format!("{name}: {}", sc.summary));
    let ((_, sched), _) = time_once("  build schedule (shared)", || sc.build_schedule());
    let active = sched.active_steps().len();
    println!(
        "  {} of {} steps have contacts ({:.0}% contact-free)",
        active,
        sched.n_steps(),
        100.0 * (1.0 - active as f64 / sched.n_steps().max(1) as f64)
    );
    for &alg in algorithms {
        run_modes(&sc, &sched, alg);
    }
}

fn main() {
    bench_scenario("sparse-single-gs", &[AlgorithmKind::Async, AlgorithmKind::FedBuff]);
    bench_scenario("walker-starlink-1584", &[AlgorithmKind::FedBuff]);
}
