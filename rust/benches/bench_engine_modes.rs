//! Dense vs contact-list vs streamed engine modes — the speedup the
//! event-driven walks buy when most time indexes carry no contact, and
//! what the streamed engine pays for computing connectivity on demand,
//! plus bit-identity checks so the bench can never report a
//! fast-but-wrong mode.
//!
//! For the precomputed modes the connectivity schedule is computed once
//! per scenario and shared, so those timings isolate the engine loop;
//! the streamed timing includes its on-demand chunk computation (that is
//! the mode's actual cost model). The mega-constellation section runs
//! `walker-starlink-4408` streamed-only — the point of ADR-0004 is that
//! the other modes cannot reasonably materialize that schedule.
//!
//! With `FEDSPACE_BENCH_JSON=<path>` the tracked medians are written as
//! JSON for the CI perf-regression gate (`fedspace bench-check`).
//!
//! Run from `rust/`: `cargo bench --bench bench_engine_modes`

use fedspace::app::{run_mock_on_schedule_fed, run_mock_on_stream_fed, FederationRun};
use fedspace::bench_report;
use fedspace::bench_util::{section, time_once};
use fedspace::cfg::{AlgorithmKind, EngineMode, Scenario};
use fedspace::connectivity::{ConnectivitySchedule, ConnectivityStream, ContactGraph};
use fedspace::testing::assert_same_run;

/// Runs per mode: the tracked medians feed the CI regression gate, and a
/// single cold sample would make a 25% budget flaky on shared runners.
const REPS: usize = 3;

/// Median of `REPS` timed runs; the first run's result is returned for the
/// bit-identity check (every rep is seed-identical anyway, ADR-0002).
fn timed_median<F: FnMut() -> fedspace::app::ExperimentOutput>(
    label: &str,
    mut f: F,
) -> (fedspace::sim::RunResult, f64) {
    let mut result = None;
    let mut dts = Vec::with_capacity(REPS);
    for rep in 0..REPS {
        let (out, dt) = time_once(&format!("{label} #{rep}"), &mut f);
        dts.push(dt);
        result.get_or_insert(out.result);
    }
    dts.sort_by(f64::total_cmp);
    (result.expect("REPS >= 1"), dts[REPS / 2])
}

fn run_modes(
    sc: &Scenario,
    sched: &ConnectivitySchedule,
    graph: Option<&ContactGraph>,
    fed: Option<FederationRun<'_>>,
    stream: &ConnectivityStream,
    alg: AlgorithmKind,
) {
    let mut cfg = sc.experiment_config(alg);
    let mut results = Vec::new();
    let mut timings = Vec::new();
    for mode in [EngineMode::Dense, EngineMode::ContactList, EngineMode::Streamed] {
        cfg.engine_mode = mode;
        let label = format!("  {} / {}", alg.name(), mode.name());
        let (result, dt) = timed_median(&label, || match mode {
            EngineMode::Streamed => {
                run_mock_on_stream_fed(&cfg, stream, fed, None).expect("run")
            }
            _ => run_mock_on_schedule_fed(&cfg, sched, graph, fed, None).expect("run"),
        });
        bench_report::record(
            &format!("engine_{}_{}_{}", sc.name.replace('-', "_"), alg.name(), mode.name()),
            dt,
        );
        results.push(result);
        timings.push(dt);
    }
    assert_same_run(&results[0], &results[1], alg.name());
    assert_same_run(&results[0], &results[2], &format!("{} streamed", alg.name()));
    println!(
        "  identical traces; engine speedup {:.2}x (contacts), {:.2}x (streamed, incl. compute)",
        timings[0] / timings[1].max(1e-9),
        timings[0] / timings[2].max(1e-9)
    );
}

fn bench_scenario(name: &str, algorithms: &[AlgorithmKind]) {
    let sc = Scenario::builtin(name).expect("builtin");
    section(&format!("{name}: {}", sc.summary));
    // informational only (not a gated key: connectivity compute has proper
    // multi-iteration medians in bench_perf)
    let ((constellation, sched), _) =
        time_once("  build schedule (shared)", || sc.build_schedule());
    // with ISLs the routed graph is shared across the grid like the
    // schedule; the streamed path routes inside its chunks instead. The
    // upload-routing table (multi-gateway scenarios) is shared the same way.
    let graph = sc.build_contact_graph(&constellation, &sched);
    let routing = sc.build_upload_routing(&constellation);
    let fed = FederationRun::of(&sc.federation, routing.as_ref());
    let (_, stream) = sc.build_stream();
    let active = sched.active_steps().len();
    println!(
        "  {} of {} steps have contacts ({:.0}% contact-free)",
        active,
        sched.n_steps(),
        100.0 * (1.0 - active as f64 / sched.n_steps().max(1) as f64)
    );
    for &alg in algorithms {
        run_modes(&sc, &sched, graph.as_ref(), fed, &stream, alg);
    }
}

/// Mega-fleet smoke timing: streamed mode only, scaled to one simulated
/// day — the configuration the CI mega-smoke step drives end to end.
fn bench_mega_streamed(name: &str) {
    let sc = Scenario::builtin(name).expect("builtin").scaled(None, Some(96));
    section(&format!("{name} (streamed only): {}", sc.summary));
    let alg = *sc.algorithms.last().expect("mega scenarios carry a grid");
    let cfg = sc.experiment_config(alg);
    let (_, stream) = sc.build_stream();
    let (result, dt) = timed_median(&format!("  {} / streamed, 96 steps", alg.name()), || {
        run_mock_on_stream_fed(&cfg, &stream, None, None).expect("run")
    });
    println!(
        "  {} satellites: rounds={} uploads={}",
        sc.constellation.n_sats(),
        result.final_round,
        result.trace.uploads
    );
    bench_report::record(&format!("engine_mega_{}_streamed", sc.name.replace('-', "_")), dt);
}

fn main() {
    bench_scenario("sparse-single-gs", &[AlgorithmKind::Async, AlgorithmKind::FedBuff]);
    bench_scenario("walker-starlink-1584", &[AlgorithmKind::FedBuff]);
    // ISL routing (ADR-0005): dense graph vs routed chunks, bit-identity
    // asserted across all three modes before any timing is reported
    bench_scenario("isl-iridium-66", &[AlgorithmKind::FedBuff]);
    // multi-gateway federation (ADR-0006): per-gateway buffers + periodic
    // reconcile, tri-mode bit-identity asserted before timing
    bench_scenario("fedspace-multi-gs", &[AlgorithmKind::FedBuff]);
    bench_mega_streamed("walker-starlink-4408");
    if let Some(path) = bench_report::flush_to_env_path().expect("bench JSON") {
        println!("\nmachine-readable results written to {path}");
    }
}
