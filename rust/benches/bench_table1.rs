//! Table 1 / Figures 3–4 — the 3-satellite illustrative example.
//!
//! Prints the executable Table 1 (Sync and Async rows match the paper
//! exactly; the FedBuff deviation is documented in fl::illustrative) and
//! benches the pure-scheduling simulator.

use fedspace::bench_util::{bench, section};
use fedspace::fl::illustrative::{self, Rule};
use fedspace::metrics::Table;

fn main() {
    section("Table 1: Sync / Async / FedBuff(M=2) on the illustrative example");
    let mut t = Table::new(&["scheme", "updates", "s=0", "s=1", "s=2", "s=5", "total", "idle"]);
    for r in illustrative::table1() {
        t.row(&[
            r.scheme.to_string(),
            r.global_updates.to_string(),
            r.staleness.count(0).to_string(),
            r.staleness.count(1).to_string(),
            r.staleness.count(2).to_string(),
            r.staleness.count(5).to_string(),
            r.total_aggregated.to_string(),
            r.idle.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("paper:  sync 1 update/3 aggregated(s=0)/5 idle · async 7/8/0 · fedbuff 3/8/0");
    println!("ours:   sync + async rows exact; fedbuff 3 updates, max staleness 5->2\n");

    section("Figure 3/4: per-scheme staleness multisets");
    for rule in [Rule::Sync, Rule::Async, Rule::FedBuff { m: 2 }] {
        let r = illustrative::run(rule);
        println!(
            "{:>8}: updates={} staleness={:?} window_connections={}",
            r.scheme,
            r.global_updates,
            r.staleness.entries().collect::<Vec<_>>(),
            r.window_connections,
        );
    }

    section("perf: illustrative simulator");
    bench("table1 (3 runs of the example)", 10, 100, || {
        let _ = illustrative::table1();
    });
}
