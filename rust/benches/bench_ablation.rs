//! Ablations over the design choices DESIGN.md calls out:
//!   1. FedBuff buffer-size sweep (the paper tuned M, best M=96)
//!   2. utility regressor: random forest vs linear
//!   3. window objective: chained-T vs the paper's frozen-T (Eq. 13)
//!   4. FedSpace search budget |R|
//! All on the mock backend so the full study runs in under a minute.

use fedspace::app::run_mock_experiment;
use fedspace::bench_util::section;
use fedspace::cfg::{AlgorithmKind, DataDist, ExperimentConfig};
use fedspace::metrics::Table;
use fedspace::rng::Rng;
use fedspace::sched::{
    generate_samples, pretrain_bank, schedule_utility_opts, MockBackend, SatForecastState,
    UtilityModel,
};

fn base() -> ExperimentConfig {
    ExperimentConfig {
        n_sats: 96,
        n_steps: 480,
        dist: DataDist::NonIid,
        n_search: 500,
        utility_samples: 200,
        n_min: 1,
        n_max: 4,
        eval_every: 4,
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    const TARGET: f64 = 0.9;

    section("ablation 1: FedBuff buffer size M (paper tuned to M=96 at K=191)");
    let mut t = Table::new(&["M", "days to 90%", "best acc", "rounds"]);
    for m in [4usize, 12, 24, 48, 96] {
        let cfg = ExperimentConfig {
            algorithm: AlgorithmKind::FedBuff,
            fedbuff_m: m,
            ..base()
        };
        let out = run_mock_experiment(&cfg, None)?;
        let r = &out.result;
        t.row(&[
            m.to_string(),
            r.trace.curve.days_to_accuracy(TARGET).map_or("-".into(), |d| format!("{d:.2}")),
            format!("{:.3}", r.trace.curve.best_accuracy()),
            r.final_round.to_string(),
        ]);
    }
    println!("{}", t.render());

    section("ablation 2: utility regressor kind");
    let mut t = Table::new(&["regressor", "days to 90%", "best acc"]);
    for kind in ["forest", "linear"] {
        let cfg = ExperimentConfig {
            algorithm: AlgorithmKind::FedSpace,
            regressor: kind.to_string(),
            ..base()
        };
        let out = run_mock_experiment(&cfg, None)?;
        let r = &out.result;
        t.row(&[
            kind.to_string(),
            r.trace.curve.days_to_accuracy(TARGET).map_or("-".into(), |d| format!("{d:.2}")),
            format!("{:.3}", r.trace.curve.best_accuracy()),
        ]);
    }
    println!("{}", t.render());

    section("ablation 3: window objective — chained-T vs frozen-T (Eq. 13)");
    // direct objective comparison: where does the predicted-optimal
    // aggregation count land under each objective?
    let backend = MockBackend::new(32, 0);
    let mut rng = Rng::new(1);
    let bank = pretrain_bank(&backend, 20, 8, 0.5, &mut rng)?;
    let (inp, tgt) = generate_samples(&backend, &bank, 400, 8, 16, 0.5, &mut rng)?;
    let mut u = UtilityModel::new("forest")?;
    u.fit(&inp, &tgt);
    let cfg = base();
    let (_, sched) = fedspace::app::build_schedule(&ExperimentConfig { n_steps: 24, ..cfg });
    let states = vec![SatForecastState::fresh(); 96];
    let mut t = Table::new(&["objective", "argmax n_agg", "objective value"]);
    for (name, chain) in [("chained-T", true), ("frozen-T (paper)", false)] {
        let mut best = (0usize, f64::NEG_INFINITY);
        let mut srng = Rng::new(7);
        for n in 1..=24 {
            let mut acc = 0.0;
            for _ in 0..8 {
                let mut cand = vec![false; 24];
                for p in srng.choose_k(24, n) {
                    cand[p] = true;
                }
                acc += schedule_utility_opts(&sched, 0, &cand, &states, &u, bank.losses[2], chain);
            }
            if acc / 8.0 > best.1 {
                best = (n, acc / 8.0);
            }
        }
        t.row(&[name.to_string(), best.0.to_string(), format!("{:.4}", best.1)]);
    }
    println!("{}", t.render());

    section("ablation 4: FedSpace search budget |R|");
    let mut t = Table::new(&["|R|", "days to 90%", "best acc"]);
    for n_search in [50usize, 500, 5000] {
        let cfg = ExperimentConfig {
            algorithm: AlgorithmKind::FedSpace,
            n_search,
            ..base()
        };
        let out = run_mock_experiment(&cfg, None)?;
        let r = &out.result;
        t.row(&[
            n_search.to_string(),
            r.trace.curve.days_to_accuracy(TARGET).map_or("-".into(), |d| format!("{d:.2}")),
            format!("{:.3}", r.trace.curve.best_accuracy()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
