//! System tests for the scenario registry and the engine time-axis modes:
//! every built-in round-trips through TOML and runs end-to-end (scaled down
//! for CI), and the dense, contact-list and streamed engines produce
//! bit-identical traces on the seed scenario `paper-fig7` — the acceptance
//! gate for the streamed-connectivity rewrite (ADR-0004).

use fedspace::app::{
    run_mock_on_schedule, run_mock_on_schedule_fed, run_mock_on_schedule_routed,
    run_mock_on_stream, run_mock_on_stream_fed, run_scenario, FederationRun,
};
use fedspace::cfg::{AlgorithmKind, EngineMode, IslMode, Scenario};
use fedspace::fl::{CodecKind, LinkSpec, ReconcilePolicy, RobustKind, RobustSpec};
use fedspace::sim::AttackSpec;
use fedspace::testing::assert_same_run;

#[test]
fn every_builtin_round_trips_through_toml() {
    let names = Scenario::builtin_names();
    assert!(names.len() >= 5);
    for name in names {
        let sc = Scenario::builtin(name).unwrap();
        let back = Scenario::from_toml_text(&sc.to_toml()).unwrap();
        assert_eq!(sc, back, "TOML round-trip changed {name}");
    }
}

#[test]
fn every_builtin_runs_end_to_end_scaled() {
    for name in Scenario::builtin_names() {
        let sc = Scenario::builtin(name).unwrap().scaled(Some(12), Some(48));
        let outs = run_scenario(&sc, None)
            .unwrap_or_else(|e| panic!("{name} failed: {e}"));
        assert_eq!(outs.len(), sc.algorithms.len(), "{name}");
        for out in &outs {
            assert!(
                !out.result.trace.curve.points.is_empty(),
                "{name}/{} produced no curve",
                out.algorithm.name()
            );
        }
    }
}

/// The acceptance gate: on `paper-fig7` (scaled for CI speed, full grid
/// incl. FedSpace) the contact-list and streamed engines' traces are
/// identical to the dense engine's, bit for bit, for all four algorithms.
#[test]
fn all_three_engine_modes_identical_on_paper_fig7() {
    let sc = Scenario::builtin("paper-fig7").unwrap().scaled(Some(24), Some(96));
    assert_eq!(sc.algorithms.len(), 4, "paper-fig7 must sweep the full grid");
    let (_, sched) = sc.build_schedule();
    let (_, stream) = sc.build_stream();
    for &alg in &sc.algorithms {
        let mut cfg = sc.experiment_config(alg);
        cfg.engine_mode = EngineMode::Dense;
        let dense = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        cfg.engine_mode = EngineMode::ContactList;
        let sparse = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_on_stream(&cfg, &stream, None).unwrap();
        assert_same_run(&dense.result, &sparse.result, alg.name());
        assert_same_run(&dense.result, &streamed.result, &format!("{} streamed", alg.name()));
    }
}

/// Downtime windows travel through the stream's per-chunk filter and land
/// in the engine identically to the dense post-pass path.
#[test]
fn streamed_engine_identical_with_downtime() {
    let mut sc = Scenario::builtin("dove-dropout").unwrap().scaled(Some(24), Some(96));
    assert!(!sc.downtime.is_empty(), "scaling dropped every downtime window");
    sc.algorithms = vec![AlgorithmKind::FedBuff];
    let (_, sched) = sc.build_schedule();
    let (_, stream) = sc.build_stream();
    let mut cfg = sc.experiment_config(AlgorithmKind::FedBuff);
    cfg.engine_mode = EngineMode::Dense;
    let dense = run_mock_on_schedule(&cfg, &sched, None).unwrap();
    cfg.engine_mode = EngineMode::Streamed;
    let streamed = run_mock_on_stream(&cfg, &stream, None).unwrap();
    assert_same_run(&dense.result, &streamed.result, "dove-dropout streamed");
}

/// The mega builtins declare the streamed engine and sweep end to end at a
/// scale CI can afford (the full 4408-satellite run is the CI smoke step).
#[test]
fn mega_builtins_run_streamed_scaled() {
    for name in ["walker-starlink-4408", "kuiper-3236"] {
        let sc = Scenario::builtin(name).unwrap();
        assert_eq!(sc.engine_mode, EngineMode::Streamed, "{name}");
        let scaled = sc.scaled(Some(40), Some(48));
        let outs = run_scenario(&scaled, None).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(outs.len(), scaled.algorithms.len(), "{name}");
        for out in &outs {
            assert!(out.result.trace.connections > 0, "{name}: no contacts reached the engine");
        }
    }
}

/// ISL acceptance gate (ADR-0005): with ISLs enabled, the dense,
/// contact-list and streamed engines produce bit-identical traces on
/// `isl-iridium-66` (scaled for CI) for all four algorithms — the routed
/// graph, the routed chunks, and the routed planning windows must agree
/// exactly.
#[test]
fn all_three_engine_modes_identical_with_isls_enabled() {
    let sc = Scenario::builtin("isl-iridium-66").unwrap().scaled(Some(24), Some(96));
    assert_eq!(sc.algorithms.len(), 4, "isl-iridium-66 must sweep the full grid");
    assert!(sc.isl.enabled());
    let (constellation, sched) = sc.build_schedule();
    let graph = sc.build_contact_graph(&constellation, &sched).expect("isl on");
    let (_, stream) = sc.build_stream();
    assert!(stream.has_isl());
    for &alg in &sc.algorithms {
        let mut cfg = sc.experiment_config(alg);
        cfg.engine_mode = EngineMode::Dense;
        let dense = run_mock_on_schedule_routed(&cfg, &sched, Some(&graph), None).unwrap();
        cfg.engine_mode = EngineMode::ContactList;
        let sparse = run_mock_on_schedule_routed(&cfg, &sched, Some(&graph), None).unwrap();
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_on_stream(&cfg, &stream, None).unwrap();
        assert_same_run(&dense.result, &sparse.result, &format!("{} isl contacts", alg.name()));
        assert_same_run(&dense.result, &streamed.result, &format!("{} isl streamed", alg.name()));
    }
}

/// Federation acceptance gate (ADR-0006): on `fedspace-multi-gs` (scaled
/// for CI, full grid incl. FedSpace with per-gateway planners) the dense,
/// contact-list and streamed engines produce bit-identical traces — the
/// shared routing table, the per-gateway buffers/policies, and the
/// periodic reconcile boundaries must agree exactly across all three
/// time-axis walks.
#[test]
fn all_three_engine_modes_identical_on_multi_gateway_federation() {
    let sc = Scenario::builtin("fedspace-multi-gs").unwrap().scaled(Some(24), Some(96));
    assert_eq!(sc.algorithms.len(), 4, "fedspace-multi-gs must sweep the full grid");
    assert_eq!(sc.federation.n_gateways(), 2);
    let (constellation, sched) = sc.build_schedule();
    let (_, stream) = sc.build_stream();
    let routing = sc.build_upload_routing(&constellation).expect("multi-gateway");
    let fed = FederationRun::of(&sc.federation, Some(&routing));
    for &alg in &sc.algorithms {
        let mut cfg = sc.experiment_config(alg);
        cfg.engine_mode = EngineMode::Dense;
        let dense = run_mock_on_schedule_fed(&cfg, &sched, None, fed, None).unwrap();
        cfg.engine_mode = EngineMode::ContactList;
        let sparse = run_mock_on_schedule_fed(&cfg, &sched, None, fed, None).unwrap();
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_on_stream_fed(&cfg, &stream, fed, None).unwrap();
        let name = alg.name();
        assert_same_run(&dense.result, &sparse.result, &format!("{name} multi-gs contacts"));
        assert_same_run(&dense.result, &streamed.result, &format!("{name} multi-gs streamed"));
        assert_eq!(dense.result.trace.gateway_aggs.len(), 2, "{}", alg.name());
    }
}

/// The ≥2-gateway acceptance criterion: per-gateway aggregation counts are
/// reported, both gateway networks carry traffic, and `Periodic` reconcile
/// changes the trace deterministically under a fixed seed.
#[test]
fn multi_gateway_periodic_reconcile_reports_and_diverges_deterministically() {
    let mut sc = Scenario::builtin("fedspace-multi-gs").unwrap().scaled(Some(24), Some(192));
    sc.algorithms = vec![AlgorithmKind::FedBuff];
    assert!(matches!(sc.federation.reconcile, ReconcilePolicy::Periodic { .. }));
    let periodic_a = &run_scenario(&sc, None).unwrap()[0].result;
    let periodic_b = &run_scenario(&sc, None).unwrap()[0].result;
    assert_same_run(periodic_a, periodic_b, "periodic multi-gs replay");
    assert!(periodic_a.trace.reconciles > 0, "the cadence never fired");
    let aggs = &periodic_a.trace.gateway_aggs;
    assert_eq!(aggs.len(), 2);
    assert_eq!(aggs.iter().sum::<usize>(), periodic_a.final_round);
    assert!(
        periodic_a.trace.gateway_uploads.iter().all(|&u| u > 0),
        "polar orbits must feed both gateways: {:?}",
        periodic_a.trace.gateway_uploads
    );
    // the same scenario with centralized reconcile produces a different
    // trace: diverged gateway replicas are visible in the learning curve
    let mut central = sc.clone();
    central.federation = central.federation.with_reconcile(ReconcilePolicy::Centralized);
    let central = &run_scenario(&central, None).unwrap()[0].result;
    assert_eq!(central.trace.reconciles, 0);
    let diverged = periodic_a
        .final_w
        .iter()
        .zip(central.final_w.iter())
        .any(|(x, y)| x.to_bits() != y.to_bits())
        || periodic_a
            .trace
            .curve
            .points
            .iter()
            .zip(central.trace.curve.points.iter())
            .any(|(p, q)| p.accuracy.to_bits() != q.accuracy.to_bits());
    assert!(diverged, "periodic reconcile left no mark on the trace");
}

/// Relays change the physics: the routed run reaches strictly more
/// satellite-contacts than the same scenario with ISLs switched off, and
/// some uploads actually arrive over relays.
#[test]
fn isls_add_reachable_contacts_and_relayed_uploads() {
    let mut on = Scenario::builtin("isl-iridium-66").unwrap().scaled(Some(24), Some(96));
    on.algorithms = vec![AlgorithmKind::FedBuff];
    let mut off = on.clone();
    off.isl.mode = IslMode::Off;
    let routed = &run_scenario(&on, None).unwrap()[0].result;
    let direct = &run_scenario(&off, None).unwrap()[0].result;
    assert!(
        routed.trace.connections > direct.trace.connections,
        "relays added no reach: routed={} direct={}",
        routed.trace.connections,
        direct.trace.connections
    );
    assert!(routed.trace.relayed > 0, "no upload ever used a relay");
    assert_eq!(direct.trace.relayed, 0, "relays counted with ISLs off");
}

/// With `IslSpec` off, the routed plumbing is inert: `run_scenario` (which
/// threads an optional graph everywhere) reproduces the plain pre-ISL
/// engine path bit for bit on the seed scenario.
#[test]
fn isl_off_scenarios_identical_to_unrouted_engine() {
    let sc = Scenario::builtin("paper-fig7").unwrap().scaled(Some(12), Some(48));
    assert!(!sc.isl.enabled());
    let (constellation, sched) = sc.build_schedule();
    assert!(sc.build_contact_graph(&constellation, &sched).is_none());
    let outs = run_scenario(&sc, None).unwrap();
    for (out, &alg) in outs.iter().zip(&sc.algorithms) {
        let plain = run_mock_on_schedule(&sc.experiment_config(alg), &sched, None).unwrap();
        assert_same_run(&out.result, &plain.result, &format!("{} isl-off", alg.name()));
        assert_eq!(out.result.trace.relayed, 0);
    }
}

/// Full-size equivalence run (minutes): `cargo test -q -- --ignored`.
#[test]
#[ignore = "full 191-satellite, 5-day run; CI uses the scaled variant"]
fn contact_list_engine_identical_on_paper_fig7_full_size() {
    let sc = Scenario::builtin("paper-fig7").unwrap();
    let (_, sched) = sc.build_schedule();
    for &alg in &sc.algorithms {
        let mut cfg = sc.experiment_config(alg);
        cfg.engine_mode = EngineMode::Dense;
        let dense = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        cfg.engine_mode = EngineMode::ContactList;
        let sparse = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        assert_same_run(&dense.result, &sparse.result, alg.name());
    }
}

#[test]
fn dropout_scenario_downtime_reaches_the_engine() {
    // in the scaled dove-dropout, failed satellites upload strictly less
    // than in the same scenario with downtime removed
    let sc = Scenario::builtin("dove-dropout").unwrap().scaled(Some(24), Some(240));
    assert!(!sc.downtime.is_empty(), "scaling dropped every downtime window");
    let mut healthy = sc.clone();
    healthy.downtime.clear();
    healthy.algorithms = vec![AlgorithmKind::FedBuff];
    let mut faulty = sc;
    faulty.algorithms = vec![AlgorithmKind::FedBuff];
    let houts = run_scenario(&healthy, None).unwrap();
    let fouts = run_scenario(&faulty, None).unwrap();
    let h = &houts[0].result;
    let f = &fouts[0].result;
    assert!(
        f.trace.connections < h.trace.connections,
        "downtime did not reduce contacts: faulty={} healthy={}",
        f.trace.connections,
        h.trace.connections
    );
}

/// Robustness acceptance gate, half 1 (ADR-0007): with the `[attack]`
/// section cleared and the default mean aggregator restored, the byz
/// builtin IS `polar-iridium-66` — the same scenario struct modulo
/// name/summary/algorithm-grid — and its runs are bit-identical to that
/// pre-robustness scenario's, dense and streamed, for all four algorithms.
/// Attack-off builds no injector and consumes no adversary randomness.
#[test]
fn attack_off_default_agg_identical_to_pre_robustness_engine() {
    let mut sc = Scenario::builtin("byz-iridium-66").unwrap();
    sc.attack = AttackSpec::default();
    sc.robust = RobustSpec::default();
    let base = Scenario::builtin("polar-iridium-66").unwrap();
    let mut stripped = sc.clone();
    stripped.name = base.name.clone();
    stripped.summary = base.summary.clone();
    stripped.algorithms = base.algorithms.clone();
    assert_eq!(stripped, base, "byz-iridium-66 must be the polar shell + attack/robust");
    let sc = sc.scaled(Some(24), Some(96));
    let base = base.scaled(Some(24), Some(96));
    let (_, sched) = sc.build_schedule();
    let (_, stream) = sc.build_stream();
    for &alg in &sc.algorithms {
        let cleared = sc.experiment_config(alg);
        let pre = base.experiment_config(alg);
        let a = run_mock_on_schedule(&cleared, &sched, None).unwrap();
        let b = run_mock_on_schedule(&pre, &sched, None).unwrap();
        let s = run_mock_on_stream(&cleared, &stream, None).unwrap();
        let name = alg.name();
        assert_same_run(&a.result, &b.result, &format!("{name} attack-off dense"));
        assert_same_run(&a.result, &s.result, &format!("{name} attack-off streamed"));
        assert_eq!(
            (a.result.trace.injected, a.result.trace.dropped, a.result.trace.corrupted),
            (0, 0, 0),
            "{name}: a clean run touched the adversary counters"
        );
    }
}

/// Robustness acceptance gate, half 2 (ADR-0007): with the adversary armed,
/// the dense, contact-list and streamed engines still produce bit-identical
/// traces on `byz-iridium-66` for the full four-algorithm grid — the
/// injector draws from its own seeded stream at the upload boundary, so the
/// attacked run is also exactly seed-reproducible.
#[test]
fn attacked_runs_identical_across_modes_and_seed_reproducible() {
    let sc = Scenario::builtin("byz-iridium-66").unwrap().scaled(Some(24), Some(96));
    assert_eq!(sc.algorithms.len(), 4, "byz-iridium-66 must sweep the full grid");
    assert!(sc.attack.enabled());
    let (_, sched) = sc.build_schedule();
    let (_, stream) = sc.build_stream();
    for &alg in &sc.algorithms {
        let mut cfg = sc.experiment_config(alg);
        cfg.engine_mode = EngineMode::Dense;
        let dense = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        let replay = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        cfg.engine_mode = EngineMode::ContactList;
        let sparse = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_on_stream(&cfg, &stream, None).unwrap();
        let name = alg.name();
        assert_same_run(&dense.result, &replay.result, &format!("{name} byz replay"));
        assert_same_run(&dense.result, &sparse.result, &format!("{name} byz contacts"));
        assert_same_run(&dense.result, &streamed.result, &format!("{name} byz streamed"));
        assert!(
            dense.result.trace.injected > 0,
            "{name}: no poisoned upload ever reached the server"
        );
    }
}

/// The attacked federation: on `byz-multi-gs` (one whole orbital plane
/// Byzantine under the arctic gateway, lossy links, per-gateway median)
/// the three engine modes agree bit for bit and both gateways still
/// aggregate — faults injected at the upload boundary are routed exactly
/// like honest uploads.
#[test]
fn byz_multi_gateway_modes_identical_under_attack() {
    let sc = Scenario::builtin("byz-multi-gs").unwrap().scaled(Some(24), Some(96));
    assert_eq!(sc.federation.n_gateways(), 2);
    assert!(sc.attack.enabled());
    let (constellation, sched) = sc.build_schedule();
    let (_, stream) = sc.build_stream();
    let routing = sc.build_upload_routing(&constellation).expect("multi-gateway");
    let fed = FederationRun::of(&sc.federation, Some(&routing));
    for &alg in &sc.algorithms {
        let mut cfg = sc.experiment_config(alg);
        cfg.engine_mode = EngineMode::Dense;
        let dense = run_mock_on_schedule_fed(&cfg, &sched, None, fed, None).unwrap();
        cfg.engine_mode = EngineMode::ContactList;
        let sparse = run_mock_on_schedule_fed(&cfg, &sched, None, fed, None).unwrap();
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_on_stream_fed(&cfg, &stream, fed, None).unwrap();
        let name = alg.name();
        assert_same_run(&dense.result, &sparse.result, &format!("{name} byz-gs contacts"));
        assert_same_run(&dense.result, &streamed.result, &format!("{name} byz-gs streamed"));
        assert!(
            dense.result.trace.injected > 0,
            "{name}: the Byzantine plane never uploaded"
        );
        assert_eq!(dense.result.trace.gateway_aggs.len(), 2, "{name}");
    }
}

/// The headline robustness claim (ADR-0007): under the scaled-gradient
/// attack, trimmed-mean and median aggregation keep the global model
/// strictly closer to the clean run's than the plain Eq.-4 mean, which the
/// poisoned uploads drag away.
#[test]
fn robust_aggregators_recover_the_model_under_attack() {
    fn l2(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| (f64::from(x) - f64::from(y)).powi(2))
            .sum::<f64>()
            .sqrt()
    }
    let mut sc = Scenario::builtin("byz-iridium-66").unwrap().scaled(Some(24), Some(192));
    sc.algorithms = vec![AlgorithmKind::FedBuff];
    // the scaled-down FedBuff buffer is small; raise the trim ratio so at
    // least one entry per side is actually trimmed (floor(0.3 m) >= 1)
    sc.robust.trim = 0.3;
    let mut clean = sc.clone();
    clean.attack = AttackSpec::default();
    clean.robust = RobustSpec::default();
    let mut mean = sc.clone();
    mean.robust = RobustSpec::default();
    let mut median = sc.clone();
    median.robust.aggregator = RobustKind::Median;
    let clean = &run_scenario(&clean, None).unwrap()[0].result;
    let attacked_mean = &run_scenario(&mean, None).unwrap()[0].result;
    let trimmed = &run_scenario(&sc, None).unwrap()[0].result;
    let median = &run_scenario(&median, None).unwrap()[0].result;
    assert_eq!(clean.trace.injected, 0);
    assert!(attacked_mean.trace.injected > 0 && trimmed.trace.injected > 0);
    let d_mean = l2(&attacked_mean.final_w, &clean.final_w);
    let d_trim = l2(&trimmed.final_w, &clean.final_w);
    let d_med = l2(&median.final_w, &clean.final_w);
    assert!(d_trim < d_mean, "trimmed-mean no closer to clean than mean: {d_trim} vs {d_mean}");
    assert!(d_med < d_mean, "median no closer to clean than mean: {d_med} vs {d_mean}");
}

/// Link acceptance gate, half 1 (ADR-0008): the compress builtin with its
/// `[link]` section cleared IS `walker-starlink-1584` — the same scenario
/// struct modulo name/summary/engine-mode — and with the link left default
/// the engine builds no codec, tracks no durations, defers nothing, and
/// reproduces the pre-link engine bit for bit on `polar-iridium-66` for
/// all four algorithms in all three time-axis modes (the generous-budget
/// identity codec run must also be a byte-level no-op end to end).
#[test]
fn link_off_identical_to_pre_link_engine() {
    let mut sc = Scenario::builtin("compress-starlink-1584").unwrap();
    sc.link = LinkSpec::default();
    let base = Scenario::builtin("walker-starlink-1584").unwrap();
    let mut stripped = sc.clone();
    stripped.name = base.name.clone();
    stripped.summary = base.summary.clone();
    stripped.engine_mode = base.engine_mode;
    assert_eq!(stripped, base, "compress-starlink-1584 must be starlink shell 1 + [link]");

    let mut sc = Scenario::builtin("polar-iridium-66").unwrap().scaled(Some(24), Some(96));
    sc.algorithms = vec![
        AlgorithmKind::Sync,
        AlgorithmKind::Async,
        AlgorithmKind::FedBuff,
        AlgorithmKind::FedSpace,
    ];
    assert!(!sc.link.enabled());
    let (_, sched_off) = sc.build_schedule();
    let (_, stream_off) = sc.build_stream();
    assert!(!sched_off.has_durations());
    // identity codec under a budget no contact can exhaust: the whole
    // capacity/codec plumbing engages (timed schedule, forecast filter,
    // encode calls) yet must change nothing
    let mut generous = sc.clone();
    generous.link = LinkSpec {
        rate_bytes_per_slot: 1 << 40,
        codec: CodecKind::Identity,
        topk_frac: 0.01,
    };
    let (_, sched_on) = generous.build_schedule();
    let (_, stream_on) = generous.build_stream();
    assert!(sched_on.has_durations() && stream_on.has_durations());
    for &alg in &sc.algorithms {
        let name = alg.name();
        let mut off = sc.experiment_config(alg);
        let mut on = generous.experiment_config(alg);
        for mode in [EngineMode::Dense, EngineMode::ContactList] {
            off.engine_mode = mode;
            on.engine_mode = mode;
            let a = run_mock_on_schedule(&off, &sched_off, None).unwrap();
            let b = run_mock_on_schedule(&on, &sched_on, None).unwrap();
            assert_same_run(&a.result, &b.result, &format!("{name} link-off {}", mode.name()));
            assert_eq!(b.result.trace.deferred, 0, "{name}: a generous budget deferred");
        }
        off.engine_mode = EngineMode::Streamed;
        on.engine_mode = EngineMode::Streamed;
        let a = run_mock_on_stream(&off, &stream_off, None).unwrap();
        let b = run_mock_on_stream(&on, &stream_on, None).unwrap();
        assert_same_run(&a.result, &b.result, &format!("{name} link-off streamed"));
        assert_eq!(a.result.trace.deferred, 0, "{name}: link-off run deferred an upload");
    }
}

/// Link acceptance gate, half 2 (ADR-0008): with the top-k codec and a
/// finite byte budget armed, the dense, contact-list and streamed engines
/// still produce bit-identical traces on `compress-starlink-1584` for the
/// whole grid — sparse payloads, capacity deferrals and the filtered
/// forecast must agree exactly across all three time-axis walks — and the
/// compressed run is exactly seed-reproducible.
#[test]
fn compressed_budgeted_runs_identical_across_modes_and_seed_reproducible() {
    let sc = Scenario::builtin("compress-starlink-1584").unwrap().scaled(Some(24), Some(96));
    assert!(sc.link.capacity_enabled());
    assert_eq!(sc.link.codec, CodecKind::TopK);
    let (_, sched) = sc.build_schedule();
    let (_, stream) = sc.build_stream();
    assert!(sched.has_durations() && stream.has_durations());
    for &alg in &sc.algorithms {
        let mut cfg = sc.experiment_config(alg);
        cfg.engine_mode = EngineMode::Dense;
        let dense = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        let replay = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        cfg.engine_mode = EngineMode::ContactList;
        let sparse = run_mock_on_schedule(&cfg, &sched, None).unwrap();
        cfg.engine_mode = EngineMode::Streamed;
        let streamed = run_mock_on_stream(&cfg, &stream, None).unwrap();
        let name = alg.name();
        assert_same_run(&dense.result, &replay.result, &format!("{name} codec replay"));
        assert_same_run(&dense.result, &sparse.result, &format!("{name} codec contacts"));
        assert_same_run(&dense.result, &streamed.result, &format!("{name} codec streamed"));
        assert!(dense.result.trace.uploads > 0, "{name}: nothing fit the budget");
    }
}

/// A budget below the smallest encoded payload starves the uplink
/// entirely: every contact defers, nothing aggregates — the deterministic
/// worst case of the capacity model.
#[test]
fn starved_link_defers_every_upload() {
    let mut sc = Scenario::builtin("compress-starlink-1584").unwrap().scaled(Some(12), Some(48));
    sc.algorithms = vec![AlgorithmKind::FedBuff];
    // top-k keeps >= 1 pair = 8 bytes; one byte per slot can never carry it
    sc.link.rate_bytes_per_slot = 1;
    let r = &run_scenario(&sc, None).unwrap()[0].result;
    assert!(r.trace.connections > 0, "the constellation never saw a station");
    assert_eq!(r.trace.uploads, 0, "an upload crossed a starved link");
    assert!(r.trace.deferred > 0, "contacts happened but none were charged");
    assert_eq!(r.final_round, 0);
}

#[test]
fn walker_and_polar_builtins_have_contacts() {
    for name in ["walker-starlink-1584", "polar-iridium-66", "sparse-single-gs"] {
        let sc = Scenario::builtin(name).unwrap().scaled(Some(12), Some(96));
        let (_, sched) = sc.build_schedule();
        let total: usize = sched.contacts.iter().map(|c| c.len()).sum();
        assert!(total > 0, "{name}: no contacts at all");
    }
}

/// ADR-0009 acceptance gate 1: turning event recording on never changes a
/// trace bit — the NullSink fast path and the recording path execute the
/// same relocated counter arithmetic, for all four algorithms in all three
/// engine modes.
#[test]
fn event_recording_never_changes_the_trace() {
    let sc = Scenario::builtin("paper-fig7").unwrap().scaled(Some(24), Some(96));
    assert_eq!(sc.algorithms.len(), 4, "paper-fig7 must sweep the full grid");
    let (_, sched) = sc.build_schedule();
    let (_, stream) = sc.build_stream();
    for &alg in &sc.algorithms {
        for mode in [EngineMode::Dense, EngineMode::ContactList, EngineMode::Streamed] {
            let mut cfg = sc.experiment_config(alg);
            cfg.engine_mode = mode;
            cfg.events.record = false;
            let off = match mode {
                EngineMode::Streamed => run_mock_on_stream(&cfg, &stream, None).unwrap(),
                _ => run_mock_on_schedule(&cfg, &sched, None).unwrap(),
            };
            cfg.events.record = true;
            let mut on = match mode {
                EngineMode::Streamed => run_mock_on_stream(&cfg, &stream, None).unwrap(),
                _ => run_mock_on_schedule(&cfg, &sched, None).unwrap(),
            };
            let label = format!("{} / {} events-on", alg.name(), mode.name());
            assert!(!on.result.events.is_empty(), "{label}: nothing recorded");
            // the off run carries no stream; clear the on run's before the
            // bit-identity check so only the derived state is compared
            on.result.events.clear();
            assert_same_run(&off.result, &on.result, &label);
        }
    }
}

/// ADR-0009 acceptance gate 2: the recorded stream is a complete account of
/// the run — replaying it through `TraceSink::apply` over a fresh trace
/// rebuilds the run's `RunTrace` exactly (counters, per-gateway vectors,
/// staleness histogram, curve bits and timing sums alike).
#[test]
fn trace_sink_replay_rebuilds_the_trace() {
    use fedspace::sim::{RunTrace, TraceSink};
    for name in ["byz-iridium-66", "compress-starlink-1584"] {
        let mut sc = Scenario::builtin(name).unwrap().scaled(Some(12), Some(48));
        sc.events.record = true;
        for out in run_scenario(&sc, None).unwrap() {
            let r = &out.result;
            let ctx = format!("{name}/{} replay", out.algorithm.name());
            assert!(!r.events.is_empty(), "{ctx}: nothing recorded");
            let mut d = RunTrace::default();
            for e in &r.events {
                TraceSink::apply(&mut d, e);
            }
            let t = &r.trace;
            assert_eq!(d.connections, t.connections, "{ctx}: connections");
            assert_eq!(d.uploads, t.uploads, "{ctx}: uploads");
            assert_eq!(d.relayed, t.relayed, "{ctx}: relayed");
            assert_eq!(d.idle, t.idle, "{ctx}: idle");
            assert_eq!(d.deferred, t.deferred, "{ctx}: deferred");
            assert_eq!(d.injected, t.injected, "{ctx}: injected");
            assert_eq!(d.dropped, t.dropped, "{ctx}: dropped");
            assert_eq!(d.corrupted, t.corrupted, "{ctx}: corrupted");
            assert_eq!(d.global_updates, t.global_updates, "{ctx}: global_updates");
            assert_eq!(d.gateway_aggs, t.gateway_aggs, "{ctx}: gateway_aggs");
            assert_eq!(d.gateway_uploads, t.gateway_uploads, "{ctx}: gateway_uploads");
            assert_eq!(d.reconciles, t.reconciles, "{ctx}: reconciles");
            assert_eq!(
                d.staleness.entries().collect::<Vec<_>>(),
                t.staleness.entries().collect::<Vec<_>>(),
                "{ctx}: staleness histogram"
            );
            assert_eq!(d.curve.points.len(), t.curve.points.len(), "{ctx}: curve length");
            for (p, q) in d.curve.points.iter().zip(t.curve.points.iter()) {
                assert_eq!(p.step, q.step, "{ctx}: curve step");
                assert_eq!(p.round, q.round, "{ctx}: curve round");
                assert_eq!(p.accuracy.to_bits(), q.accuracy.to_bits(), "{ctx}: accuracy bits");
                assert_eq!(p.loss.to_bits(), q.loss.to_bits(), "{ctx}: loss bits");
            }
            // timing sums replay bit-identically: the run accumulated them
            // through the very same apply() on the very same event values
            assert_eq!(d.t_train_s.to_bits(), t.t_train_s.to_bits(), "{ctx}: t_train_s");
            assert_eq!(d.t_agg_s.to_bits(), t.t_agg_s.to_bits(), "{ctx}: t_agg_s");
            assert_eq!(d.t_eval_s.to_bits(), t.t_eval_s.to_bits(), "{ctx}: t_eval_s");
        }
    }
}
