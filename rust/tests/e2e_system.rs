//! End-to-end system tests: the full Algorithm-1 loop over real orbital
//! connectivity, with both the mock backend (all four algorithms, fast) and
//! the PJRT backend (real artifacts, real synthetic-fMoW batches — the
//! complete three-layer path).

use fedspace::app::run_mock_experiment;
#[cfg(feature = "pjrt")]
use fedspace::app::run_pjrt_experiment;
use fedspace::cfg::{AlgorithmKind, DataDist, ExperimentConfig};

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        n_sats: 16,
        n_steps: 96,
        fedbuff_m: 6,
        i0: 24,
        n_min: 2,
        n_max: 8,
        n_search: 100,
        utility_samples: 80,
        model_size: "small".to_string(),
        artifacts_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string(),
        n_train: 800,
        n_val: 64,
        eval_every: 8,
        ..Default::default()
    }
}

#[test]
fn mock_end_to_end_all_algorithms_both_dists() {
    for dist in [DataDist::Iid, DataDist::NonIid] {
        for alg in [
            AlgorithmKind::Sync,
            AlgorithmKind::Async,
            AlgorithmKind::FedBuff,
            AlgorithmKind::FedSpace,
        ] {
            let cfg = ExperimentConfig { algorithm: alg, dist, ..base_cfg() };
            let out = run_mock_experiment(&cfg, None).unwrap();
            let r = &out.result;
            assert!(r.trace.connections > 0, "{alg:?}/{dist:?}: no connections");
            assert!(
                r.trace.uploads + r.trace.idle == r.trace.connections,
                "{alg:?}/{dist:?}: contact accounting broken"
            );
            // aggregated gradients never exceed uploads
            assert!(
                r.trace.staleness.total() as usize <= r.trace.uploads,
                "{alg:?}/{dist:?}: staleness trace overcounts"
            );
        }
    }
}

#[test]
fn mock_sync_idles_most_and_async_is_stalest() {
    let mut idle_frac = std::collections::BTreeMap::new();
    let mut max_stal = std::collections::BTreeMap::new();
    for alg in [AlgorithmKind::Sync, AlgorithmKind::Async, AlgorithmKind::FedBuff] {
        let cfg = ExperimentConfig { algorithm: alg, ..base_cfg() };
        let out = run_mock_experiment(&cfg, None).unwrap();
        idle_frac.insert(alg.name(), out.result.trace.idle_fraction());
        max_stal.insert(alg.name(), out.result.trace.staleness.max_key().unwrap_or(0));
    }
    assert!(idle_frac["sync"] >= idle_frac["fedbuff"]);
    assert!(idle_frac["fedbuff"] >= idle_frac["async"] - 1e-9);
    assert!(max_stal["async"] >= max_stal["fedbuff"]);
}

#[test]
#[cfg(feature = "pjrt")]
fn pjrt_end_to_end_fedbuff_trains() {
    // The full three-layer path on a real small workload (CI-sized).
    let cfg = ExperimentConfig {
        algorithm: AlgorithmKind::FedBuff,
        fedbuff_m: 4,
        n_sats: 12,
        n_steps: 96,
        n_train: 800,
        n_val: 64,
        eval_every: 24,
        lr: 1.0,
        ..base_cfg()
    };
    let out = run_pjrt_experiment(&cfg, 64, None).unwrap();
    let r = &out.result;
    assert!(r.final_round > 0, "no global updates");
    let first = r.trace.curve.points.first().unwrap();
    let last = r.trace.curve.points.last().unwrap();
    // a short CI-sized run: the loss must clearly move off ln(62) even if
    // top-1 accuracy barely registers yet (the long Figure-6 runs live in
    // benches/bench_fig6_table2)
    assert!(
        last.loss < first.loss - 0.05,
        "no learning: loss {} -> {}",
        first.loss,
        last.loss
    );
    assert!(r.trace.curve.points.iter().all(|p| p.loss.is_finite()));
}

#[test]
#[cfg(feature = "pjrt")]
fn pjrt_noniid_partition_runs() {
    let cfg = ExperimentConfig {
        algorithm: AlgorithmKind::Async,
        dist: DataDist::NonIid,
        n_sats: 8,
        n_steps: 16,
        n_train: 400,
        n_val: 32,
        eval_every: 8,
        ..base_cfg()
    };
    let out = run_pjrt_experiment(&cfg, 32, None).unwrap();
    assert!(out.result.trace.connections > 0);
}

#[test]
fn mock_training_survives_contact_dropout() {
    // Failure injection: 25% of forecast contacts never happen (weather,
    // pointing). FedBuff and FedSpace must still converge — the engine's
    // state machine cannot deadlock on missed uploads.
    use fedspace::connectivity::ConnectivityParams;
    use fedspace::fl::CpuAggregator;
    use fedspace::orbit::{planet_ground_stations, planet_labs_like};
    use fedspace::rng::Rng;
    use fedspace::sim::{Engine, EngineConfig, MockTrainer};

    let constellation = planet_labs_like(24, 0);
    let full = fedspace::connectivity::ConnectivitySchedule::compute(
        &constellation,
        &planet_ground_stations(),
        192,
        ConnectivityParams::default(),
    );
    let mut rng = Rng::new(11);
    let degraded = full.with_dropout(0.25, &mut rng);
    for alg in [
        fedspace::cfg::AlgorithmKind::Async,
        fedspace::cfg::AlgorithmKind::FedBuff,
    ] {
        let trainer = MockTrainer::new(16, 24, 0.3, 0);
        let mut agg = CpuAggregator;
        let cfg = EngineConfig { algorithm: alg, fedbuff_m: 6, ..Default::default() };
        let mut e = Engine::builder()
            .schedule(&degraded)
            .trainer(&trainer)
            .aggregator(&mut agg)
            .config(cfg)
            .build();
        let r = e.run().unwrap();
        assert!(r.final_round > 0, "{alg:?} made no progress under dropout");
        let first = r.trace.curve.points.first().unwrap().accuracy;
        assert!(
            r.trace.curve.best_accuracy() > first,
            "{alg:?} did not improve under dropout"
        );
    }
}
