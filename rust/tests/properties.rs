//! Property-based tests over coordinator invariants (routing, batching,
//! scheduling state) using the in-crate mini property framework.

use fedspace::connectivity::{
    ConnectivityParams, ConnectivitySchedule, ConnectivityStream, ContactGraph, IslParams,
    IslTopology, ScheduleChunk,
};
use fedspace::fl::illustrative;
use fedspace::fl::{normalized_weights, Buffer, GradientEntry};
use fedspace::orbit::{
    planet_ground_stations, planet_labs_like, Constellation, DowntimeWindow, WalkerPattern,
    WalkerSpec,
};
use fedspace::rng::Rng;
use fedspace::sched::{
    forecast_window, random_search, random_search_serial, SatForecastState, SearchParams,
    UtilityModel,
};
use fedspace::testing::property;

fn random_schedule(rng: &mut Rng, k: usize, steps: usize) -> ConnectivitySchedule {
    let sets: Vec<Vec<usize>> = (0..steps)
        .map(|_| {
            let n = rng.gen_range(0, k + 1);
            let mut v = rng.choose_k(k, n);
            v.sort_unstable();
            v
        })
        .collect();
    ConnectivitySchedule::from_sets(sets, k)
}

#[test]
fn prop_staleness_weights_normalized_and_monotone() {
    property(200, |rng| {
        let n = rng.gen_range(1, 40);
        let st: Vec<usize> = (0..n).map(|_| rng.gen_range(0, 20)).collect();
        let alpha = rng.gen_f64(0.0, 2.0);
        let w = normalized_weights(&st, alpha);
        let sum: f32 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "sum={sum}");
        assert!(w.iter().all(|&x| x >= 0.0));
        // weight ordering inverse to staleness ordering
        for i in 0..n {
            for j in 0..n {
                if st[i] < st[j] && alpha > 0.0 {
                    assert!(w[i] >= w[j], "s{}={} s{}={}", i, st[i], j, st[j]);
                }
            }
        }
    });
}

#[test]
fn prop_buffer_counts_consistent() {
    property(100, |rng| {
        let mut buf = Buffer::new();
        let n = rng.gen_range(0, 60);
        let mut sats = std::collections::BTreeSet::new();
        for _ in 0..n {
            let sat = rng.gen_range(0, 10);
            sats.insert(sat);
            buf.push(GradientEntry {
                sat,
                staleness: rng.gen_range(0, 8),
                grad: vec![0.0; 3].into(),
                n_samples: 1,
            });
        }
        assert_eq!(buf.len(), n);
        assert_eq!(buf.n_sats(), sats.len());
        let drained = buf.drain();
        assert_eq!(drained.len(), n);
        assert!(buf.is_empty() && buf.n_sats() == 0);
    });
}

#[test]
fn prop_connectivity_schedule_lookup_consistency() {
    property(60, |rng| {
        let k = rng.gen_range(1, 12);
        let steps = rng.gen_range(1, 60);
        let s = random_schedule(rng, k, steps);
        // connected() agrees with sets; prev/next agree with contacts
        for i in 0..steps {
            for sat in 0..k {
                assert_eq!(s.connected(sat, i), s.sets[i].contains(&sat));
            }
        }
        for sat in 0..k {
            for i in 0..steps {
                if let Some(p) = s.prev_contact(sat, i) {
                    assert!(p < i && s.connected(sat, p));
                    // nothing between p and i
                    for l in (p + 1)..i {
                        assert!(!s.connected(sat, l));
                    }
                }
                if let Some(nx) = s.next_contact(sat, i) {
                    assert!(nx >= i && s.connected(sat, nx));
                }
            }
        }
    });
}

#[test]
fn prop_bitset_view_matches_sorted_views() {
    // the packed-u64 connectivity view must agree with the legacy sorted
    // Vec views on random schedules, including multi-word steps (k > 64)
    property(60, |rng| {
        let k = rng.gen_range(1, 140);
        let steps = rng.gen_range(1, 40);
        let s = random_schedule(rng, k, steps);
        assert_eq!(s.words_per_step(), k.div_ceil(64));
        for i in 0..steps {
            // word iteration reconstructs the sorted set exactly
            let mut rebuilt = Vec::new();
            for (w, &word) in s.step_words(i).iter().enumerate() {
                let mut word = word;
                while word != 0 {
                    rebuilt.push(w * 64 + word.trailing_zeros() as usize);
                    word &= word - 1;
                }
            }
            assert_eq!(rebuilt, s.sets[i], "step {i}");
            assert_eq!(s.sats_at(i), &s.sets[i][..]);
            // O(1) connected() agrees with binary search on the sorted view
            for sat in 0..k {
                assert_eq!(
                    s.connected(sat, i),
                    s.sets[i].binary_search(&sat).is_ok(),
                    "sat {sat} step {i}"
                );
            }
        }
        assert!(!s.connected(k, 0));
    });
}

#[test]
fn prop_parallel_search_matches_serial_reference() {
    // parallel candidate scoring must return bit-identical schedules and
    // utilities to the legacy serial loop for any seed / search size
    property(25, |rng| {
        let k = rng.gen_range(1, 8);
        let i0 = rng.gen_range(4, 30);
        let s = random_schedule(rng, k, i0);
        let n_min = rng.gen_range(1, i0.min(4) + 1);
        let n_max = rng.gen_range(n_min, i0 + 1);
        let n_search = rng.gen_range(1, 200);
        let u = UtilityModel::new("forest").unwrap();
        let params = SearchParams { i0, n_min, n_max, n_search };
        let states = vec![SatForecastState::fresh(); k];
        let seed = rng.next_u64();
        let mut r1 = Rng::new(seed);
        let mut r2 = Rng::new(seed);
        let a = random_search(&s, 0, &states, &u, 1.0, &params, &mut r1);
        let b = random_search_serial(&s, 0, &states, &u, 1.0, &params, &mut r2);
        assert_eq!(a.0, b.0, "seed={seed:#x}");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "seed={seed:#x}");
        assert_eq!(r1.next_u64(), r2.next_u64(), "rng stream diverged");
    });
}

#[test]
fn prop_stream_chunks_bit_identical_to_dense_compute() {
    // a ConnectivityStream concatenated over its chunks must equal the
    // all-at-once compute + downtime post-pass exactly (not approximately:
    // both paths share the same sampling helpers on absolute step indexes)
    // for any fleet size, horizon, chunk length, and downtime windows —
    // including windows whose boundaries land exactly on chunk edges
    property(8, |rng| {
        let k = rng.gen_range(1, 14);
        let steps = rng.gen_range(1, 50);
        let chunk_len = rng.gen_range(1, steps + 10);
        let mut windows = Vec::new();
        for _ in 0..rng.gen_range(0, 4) {
            let sat = rng.gen_range(0, k);
            let from = if rng.gen_bool(0.5) {
                // snap the outage start onto a chunk edge
                (rng.gen_range(0, steps) / chunk_len) * chunk_len
            } else {
                rng.gen_range(0, steps)
            };
            let until = (from + 1 + rng.gen_range(0, chunk_len + 2)).min(steps);
            windows.push(DowntimeWindow { sat, from_step: from, until_step: until });
        }
        let c = planet_labs_like(k, rng.next_u64()).with_downtime(windows);
        let gs = planet_ground_stations();
        let params = ConnectivityParams::default();
        let dense = ConnectivitySchedule::compute(&c, &gs, steps, params.clone())
            .with_downtime(&c.downtime);
        let stream = ConnectivityStream::new(&c, &gs, steps, params, chunk_len);
        let mut chunk = ScheduleChunk::default();
        let mut active = Vec::new();
        for ci in 0..stream.n_chunks() {
            stream.fill_chunk(ci, &mut chunk);
            for i in chunk.start()..chunk.end() {
                assert_eq!(
                    chunk.sats_at(i),
                    dense.sats_at(i),
                    "step {i} (chunk_len {chunk_len}, k {k})"
                );
            }
            active.extend_from_slice(chunk.active_steps());
        }
        assert_eq!(active, dense.active_steps(), "event lists must concatenate");
    });
}

/// Random Walker shell + random ISL parameters for the routing properties.
fn random_topology(rng: &mut Rng) -> (Constellation, IslParams, usize) {
    let planes = rng.gen_range(1, 7);
    let per_plane = rng.gen_range(1, 8);
    let n = planes * per_plane;
    let c = Constellation::walker(&WalkerSpec {
        pattern: if rng.gen_bool(0.5) { WalkerPattern::Star } else { WalkerPattern::Delta },
        n_sats: n,
        planes,
        phasing: rng.gen_range(0, n),
        alt_m: rng.gen_f64(400e3, 1200e3),
        inc_deg: rng.gen_f64(30.0, 98.0),
    });
    let params = IslParams {
        max_hops: rng.gen_range(1, 5),
        hop_delay_slots: rng.gen_range(0, 3),
        cross_plane: rng.gen_bool(0.5),
        max_range_m: rng.gen_f64(500e3, 8000e3),
        t0_s: 900.0,
    };
    (c, params, n)
}

#[test]
fn prop_isl_adjacency_symmetric_never_reflexive() {
    property(20, |rng| {
        let (c, params, n) = random_topology(rng);
        let topo = IslTopology::new(&c, params).unwrap();
        for i in [0usize, rng.gen_range(1, 50)] {
            for a in 0..n {
                assert!(!topo.is_linked(a, a, i), "self-link at sat {a}");
                for b in (a + 1)..n {
                    assert_eq!(
                        topo.is_linked(a, b, i),
                        topo.is_linked(b, a, i),
                        "asymmetric link {a}<->{b} at step {i}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_isl_routing_hop_bounded_and_supersets_direct() {
    property(20, |rng| {
        let (c, params, n) = random_topology(rng);
        let topo = IslTopology::new(&c, params).unwrap();
        let steps = rng.gen_range(1, 20);
        let sched = random_schedule(rng, n, steps);
        let graph = ContactGraph::build(&topo, &sched);
        for i in 0..steps {
            let reach = graph.sats_at(i);
            let hops = graph.hops_at(i);
            assert_eq!(reach.len(), hops.len());
            // sorted ascending, no duplicates
            assert!(reach.windows(2).all(|w| w[0] < w[1]), "unsorted reach at {i}");
            // hop-bounded routing never exceeds max_hops
            for (&s, &h) in reach.iter().zip(hops.iter()) {
                assert!(
                    (h as usize) <= params.max_hops,
                    "sat {s} at {h} hops > {} (step {i})",
                    params.max_hops
                );
            }
            // reach ⊇ direct, with hop 0 exactly on the direct contacts
            for &s in sched.sats_at(i) {
                let j = reach.binary_search(&s).unwrap_or_else(|_| {
                    panic!("direct contact {s} missing from reach at step {i}")
                });
                assert_eq!(hops[j], 0, "direct contact {s} has nonzero hops");
            }
            for (&s, &h) in reach.iter().zip(hops.iter()) {
                assert_eq!(h == 0, sched.sats_at(i).contains(&s), "hop-0 set != C_i at {i}");
            }
            // no ground contact, no reach (relays need a sink)
            if sched.sats_at(i).is_empty() {
                assert!(reach.is_empty(), "reach without a sink at step {i}");
            }
        }
    });
}

#[test]
fn prop_routed_chunks_bit_identical_to_dense_graph() {
    // the streamed per-chunk routing must concatenate to exactly the dense
    // ContactGraph — same BFS on absolute step indexes (ADR-0005), for any
    // shell shape, chunk length, range gate, and downtime windows
    property(6, |rng| {
        let (c, params, n) = random_topology(rng);
        let steps = rng.gen_range(1, 40);
        let chunk_len = rng.gen_range(1, steps + 8);
        let mut windows = Vec::new();
        for _ in 0..rng.gen_range(0, 3) {
            let sat = rng.gen_range(0, n);
            let from = rng.gen_range(0, steps);
            let until = (from + 1 + rng.gen_range(0, chunk_len + 2)).min(steps);
            windows.push(DowntimeWindow { sat, from_step: from, until_step: until });
        }
        let c = c.with_downtime(windows);
        let gs = planet_ground_stations();
        let cparams = ConnectivityParams::default();
        let topo = IslTopology::new(&c, params).unwrap();
        let dense = ConnectivitySchedule::compute(&c, &gs, steps, cparams.clone())
            .with_downtime(&c.downtime);
        let graph = ContactGraph::build(&topo, &dense);
        let stream = ConnectivityStream::new(&c, &gs, steps, cparams, chunk_len).with_isl(topo);
        let mut chunk = ScheduleChunk::default();
        let mut events = Vec::new();
        for ci in 0..stream.n_chunks() {
            stream.fill_chunk(ci, &mut chunk);
            for i in chunk.start()..chunk.end() {
                let (s, h) = chunk.contacts_at(i);
                assert_eq!(s, graph.sats_at(i), "reach at step {i} (chunk_len {chunk_len})");
                assert_eq!(h, graph.hops_at(i), "hops at step {i} (chunk_len {chunk_len})");
            }
            events.extend_from_slice(chunk.events());
        }
        assert_eq!(events, graph.active_steps(), "event lists must concatenate");
    });
}

#[test]
fn prop_forecast_conservation() {
    // gradients consumed by forecast aggregations never exceed contacts,
    // and idle + uploads ≤ contacts
    property(80, |rng| {
        let k = rng.gen_range(1, 10);
        let steps = rng.gen_range(2, 40);
        let s = random_schedule(rng, k, steps);
        let schedule: Vec<bool> = (0..steps).map(|_| rng.gen_bool(0.4)).collect();
        let states: Vec<SatForecastState> = (0..k)
            .map(|_| SatForecastState {
                pending: rng.gen_bool(0.5),
                staleness_now: rng.gen_range(0, 5),
                holds_current: rng.gen_bool(0.5),
                has_data: rng.gen_bool(0.9),
            })
            .collect();
        let f = forecast_window(&s, 0, &schedule, &states);
        let consumed: usize = f.aggregations.iter().map(|a| a.len()).sum();
        let planned: usize = schedule.iter().filter(|&&b| b).count();
        assert!(f.aggregations.len() <= planned);
        // each satellite uploads at most (contacts + initial pending)
        let max_uploads: usize =
            s.contacts.iter().map(|c| c.len()).sum::<usize>() + k;
        assert!(consumed <= max_uploads);
        assert!(f.idle <= f.contacts);
    });
}

#[test]
fn prop_random_search_schedule_within_bounds() {
    property(40, |rng| {
        let k = rng.gen_range(1, 8);
        let i0 = rng.gen_range(4, 32);
        let s = random_schedule(rng, k, i0);
        let n_min = rng.gen_range(1, i0.min(5) + 1);
        let n_max = rng.gen_range(n_min, i0 + 1);
        let params = SearchParams { i0, n_min, n_max, n_search: 15 };
        let u = UtilityModel::new("forest").unwrap();
        let states = vec![SatForecastState::fresh(); k];
        let (best, util) = random_search(&s, 0, &states, &u, 1.0, &params, rng);
        let n = best.iter().filter(|&&b| b).count();
        assert!(n >= n_min && n <= n_max);
        assert!(util.is_finite());
    });
}

#[test]
fn prop_illustrative_invariants_hold_for_any_m() {
    // for every buffer size M, the illustrative example preserves Appendix
    // A's identities: FedBuff(1) == Async, FedBuff(K) == Sync, and
    // aggregated ≤ total uploads
    for m in 1..=3 {
        let r = illustrative::run(illustrative::Rule::FedBuff { m });
        assert!(r.total_aggregated <= r.window_connections + 3);
        assert!(r.global_updates <= r.total_aggregated);
    }
    let asy = illustrative::run(illustrative::Rule::Async);
    let fb1 = illustrative::run(illustrative::Rule::FedBuff { m: 1 });
    assert_eq!(asy.global_updates, fb1.global_updates);
    assert_eq!(asy.idle, fb1.idle);
}

#[test]
fn prop_robust_aggregators_permutation_invariant() {
    // reordering the buffer must not change a single bit of the robust
    // update (ADR-0007): the median sorts per coordinate, the trimmed mean
    // sorts (value, weight) pairs, and multi-Krum breaks score ties on the
    // entry's intrinsic identity. The generators keep clear of the two
    // documented mean fallbacks (trim below 1/n, Krum with n < f + 3) —
    // the reference mean accumulates in entry order and is exempt.
    use fedspace::fl::server::ServerAggregator;
    use fedspace::fl::{CoordinateMedian, MultiKrum, TrimmedMean};
    property(25, |rng| {
        let d = rng.gen_range(1, 40);
        let n = rng.gen_range(3, 12);
        let w0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut entries: Vec<GradientEntry> = (0..n)
            .map(|sat| GradientEntry {
                sat,
                staleness: rng.gen_range(0, 6),
                grad: (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect::<Vec<f32>>().into(),
                n_samples: 1,
            })
            .collect();
        let alpha = rng.gen_f64(0.0, 2.0);
        let trim = rng.gen_f64(1.0 / n as f64, 0.49); // floor(trim n) >= 1
        let f = rng.gen_range(0, (n - 2).min(5)); // n >= f + 3
        let apply = |which: usize, entries: &[GradientEntry]| -> Vec<f32> {
            let mut w = w0.clone();
            match which {
                0 => CoordinateMedian.aggregate(&mut w, entries, alpha).unwrap(),
                1 => TrimmedMean { trim }.aggregate(&mut w, entries, alpha).unwrap(),
                _ => MultiKrum { f, m: 0 }.aggregate(&mut w, entries, alpha).unwrap(),
            }
            w
        };
        let baseline: Vec<Vec<f32>> = (0..3).map(|which| apply(which, &entries)).collect();
        for _ in 0..3 {
            rng.shuffle(&mut entries);
            for (which, name) in ["median", "trimmed-mean", "multi-krum"].iter().enumerate() {
                let w = apply(which, &entries);
                for (j, (x, y)) in w.iter().zip(&baseline[which]).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{name} not permutation-invariant at dim {j} (n={n} d={d})"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_trimmed_mean_at_zero_trim_is_the_reference_mean() {
    // any trim fraction below 1/n trims nothing, and the spec says that
    // case IS the CpuAggregator — bit for bit, so a [robust] section with
    // trim 0 cannot perturb a pre-robustness trace
    use fedspace::fl::server::{CpuAggregator, ServerAggregator};
    use fedspace::fl::TrimmedMean;
    property(40, |rng| {
        let d = rng.gen_range(1, 60);
        let n = rng.gen_range(1, 12);
        let w0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let entries: Vec<GradientEntry> = (0..n)
            .map(|sat| GradientEntry {
                sat,
                staleness: rng.gen_range(0, 8),
                grad: (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect::<Vec<f32>>().into(),
                n_samples: 1,
            })
            .collect();
        let alpha = rng.gen_f64(0.0, 2.0);
        let trim = if rng.gen_bool(0.5) { 0.0 } else { rng.gen_f64(0.0, 0.99 / n as f64) };
        let mut a = w0.clone();
        let mut b = w0;
        TrimmedMean { trim }.aggregate(&mut a, &entries, alpha).unwrap();
        CpuAggregator.aggregate(&mut b, &entries, alpha).unwrap();
        for (j, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "dim {j} (trim={trim} n={n})");
        }
    });
}

#[test]
fn prop_trimmed_mean_contained_by_honest_range_under_bounded_adversaries() {
    // the ADR-0007 containment guarantee: with at most t = floor(trim n)
    // Byzantine entries, every coordinate of the trimmed-mean update lies
    // inside the honest values' [min, max] for that coordinate — arbitrary
    // poisoned magnitudes are clipped out, never averaged in
    use fedspace::fl::server::ServerAggregator;
    use fedspace::fl::TrimmedMean;
    property(40, |rng| {
        let d = rng.gen_range(1, 30);
        let n_adv = rng.gen_range(1, 4);
        let n_honest = rng.gen_range(2 * n_adv + 1, 13);
        let n = n_honest + n_adv;
        // trim fraction chosen so t >= n_adv (containment precondition)
        let trim = rng.gen_f64(n_adv as f64 / n as f64, 0.49);
        let honest: Vec<Vec<f32>> = (0..n_honest)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut entries: Vec<GradientEntry> = honest
            .iter()
            .enumerate()
            .map(|(sat, g)| GradientEntry {
                sat,
                staleness: rng.gen_range(0, 6),
                grad: g.clone().into(),
                n_samples: 1,
            })
            .collect();
        for a in 0..n_adv {
            // adversaries push huge values of either sign
            let scale = if rng.gen_bool(0.5) { 1e6 } else { -1e6 };
            entries.push(GradientEntry {
                sat: n_honest + a,
                staleness: rng.gen_range(0, 6),
                grad: (0..d).map(|_| scale * (1.0 + rng.next_f32())).collect::<Vec<f32>>().into(),
                n_samples: 1,
            });
        }
        rng.shuffle(&mut entries);
        let mut w = vec![0.0f32; d];
        TrimmedMean { trim }.aggregate(&mut w, &entries, rng.gen_f64(0.0, 2.0)).unwrap();
        for j in 0..d {
            let lo = honest.iter().map(|g| g[j]).fold(f32::INFINITY, f32::min);
            let hi = honest.iter().map(|g| g[j]).fold(f32::NEG_INFINITY, f32::max);
            let eps = 1e-4 * (1.0 + lo.abs().max(hi.abs()));
            assert!(
                w[j] >= lo - eps && w[j] <= hi + eps,
                "dim {j}: update {} escaped honest range [{lo}, {hi}] \
                 (n_honest={n_honest} n_adv={n_adv} trim={trim})",
                w[j]
            );
        }
    });
}

#[test]
fn prop_cpu_aggregation_linear_in_weights() {
    // Eq. (4) with equal stalenesses is a plain average: w' - w must equal
    // the mean gradient, for any buffer size and dimension
    use fedspace::fl::server::{CpuAggregator, ServerAggregator};
    property(60, |rng| {
        let d = rng.gen_range(1, 50);
        let n = rng.gen_range(1, 12);
        let w0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let entries: Vec<GradientEntry> = (0..n)
            .map(|sat| GradientEntry {
                sat,
                staleness: 2, // equal -> uniform weights
                grad: (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect::<Vec<f32>>().into(),
                n_samples: 1,
            })
            .collect();
        let mut w = w0.clone();
        CpuAggregator.aggregate(&mut w, &entries, 0.7).unwrap();
        for j in 0..d {
            let mean: f32 =
                entries.iter().map(|e| e.grad.at(j)).sum::<f32>() / n as f32;
            let got = w[j] - w0[j];
            assert!((got - mean).abs() < 1e-4, "dim {j}: {got} vs {mean}");
        }
    });
}

#[test]
fn prop_topk_ships_exact_bits_and_loses_nothing() {
    // ADR-0008's lossless-delay guarantee, coordinate by coordinate: after
    // error-feedback compensation x = grad + residual_in, every selected
    // coordinate ships x's exact f32 bits, every dropped coordinate lands
    // bit-for-bit in the residual (zeroed where shipped), and no dropped
    // magnitude exceeds the smallest kept one
    use fedspace::fl::{CodecKind, LinkSpec, Update, UpdateCodec};
    property(60, |rng| {
        let d = rng.gen_range(1, 80);
        let spec = LinkSpec {
            codec: CodecKind::TopK,
            topk_frac: rng.gen_f64(0.01, 1.0),
            ..Default::default()
        };
        let mut codec = UpdateCodec::new(&spec, rng.next_u64());
        let mut residual: Vec<f32> = if rng.gen_bool(0.5) {
            (0..d).map(|_| rng.normal_f32(0.0, 0.3)).collect()
        } else {
            Vec::new() // lazily sized on first use
        };
        let grad: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut x = grad.clone();
        for (xi, r) in x.iter_mut().zip(residual.iter()) {
            *xi += *r;
        }
        let out = codec.encode(grad, &mut residual);
        let Update::Sparse { dim, idx, val } = out else { panic!("top-k must go sparse") };
        assert_eq!(dim, d);
        assert_eq!(idx.len(), spec.topk_k(d));
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must be sorted unique");
        let kept_min = val.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
        for j in 0..d {
            match idx.binary_search(&(j as u32)) {
                Ok(p) => {
                    assert_eq!(val[p].to_bits(), x[j].to_bits(), "shipped coord {j}");
                    assert_eq!(residual[j].to_bits(), 0.0f32.to_bits(), "coord {j}");
                }
                Err(_) => {
                    assert_eq!(residual[j].to_bits(), x[j].to_bits(), "dropped coord {j}");
                    assert!(x[j].abs() <= kept_min, "dropped {j} beats a kept coord");
                }
            }
        }
    });
}

#[test]
fn prop_identity_codec_never_perturbs_anything() {
    // the codec-off ≡ PR 6 bit-identity argument rests on Identity being a
    // byte-level no-op that consumes no randomness: any two encoder seeds
    // must emit the same dense bits and leave the residual untouched
    use fedspace::fl::{CodecKind, LinkSpec, Update, UpdateCodec};
    property(60, |rng| {
        let d = rng.gen_range(1, 60);
        let spec = LinkSpec {
            rate_bytes_per_slot: rng.gen_range(0, 1000) as u64,
            codec: CodecKind::Identity,
            topk_frac: 1.0,
        };
        let grad: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bits: Vec<u32> = grad.iter().map(|v| v.to_bits()).collect();
        let junk: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut out = Vec::new();
        for seed in [rng.next_u64(), rng.next_u64()] {
            let mut codec = UpdateCodec::new(&spec, seed);
            let mut residual = junk.clone();
            let enc = codec.encode(grad.clone(), &mut residual);
            let Update::Dense(v) = enc else { panic!("identity must stay dense") };
            assert_eq!(v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), bits);
            for (r, j) in residual.iter().zip(junk.iter()) {
                assert_eq!(r.to_bits(), j.to_bits(), "residual was touched");
            }
            out.push(v);
        }
        assert_eq!(out[0], out[1], "identity output depended on the codec seed");
    });
}

#[test]
fn prop_codec_stream_is_seed_reproducible() {
    // two encoders built from the same run seed must replay the identical
    // randomized quantization over a whole sequence of uploads — bits of
    // every update AND every carried residual (this is what makes codec
    // runs seed-reproducible end to end)
    use fedspace::fl::{CodecKind, LinkSpec, UpdateCodec};
    property(30, |rng| {
        let d = rng.gen_range(1, 50);
        let uploads = rng.gen_range(1, 6);
        let spec = LinkSpec {
            codec: if rng.gen_bool(0.5) { CodecKind::QuantQ8 } else { CodecKind::TopK },
            topk_frac: rng.gen_f64(0.05, 1.0),
            ..Default::default()
        };
        let seed = rng.next_u64();
        let grads: Vec<Vec<f32>> = (0..uploads)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut a = UpdateCodec::new(&spec, seed);
        let mut b = UpdateCodec::new(&spec, seed);
        let (mut ra, mut rb) = (Vec::new(), Vec::new());
        for g in &grads {
            let ua = a.encode(g.clone(), &mut ra);
            let ub = b.encode(g.clone(), &mut rb);
            for (x, y) in ua.values().iter().zip(ub.values()) {
                assert_eq!(x.to_bits(), y.to_bits(), "update bits diverged");
            }
            assert_eq!(ua.len(), ub.len());
            for (x, y) in ra.iter().zip(rb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "residual bits diverged");
            }
        }
    });
}

#[test]
fn prop_sparse_aggregation_matches_the_densified_oracle() {
    // a buffer mixing sparse top-k wire forms with plain dense uploads must
    // aggregate bit-for-bit like the same buffer with every sparse entry
    // densified first — for the reference mean and the per-coordinate
    // median alike (the lazy-densify path cannot be a different algorithm)
    use fedspace::fl::server::{CpuAggregator, ServerAggregator};
    use fedspace::fl::{CodecKind, CoordinateMedian, LinkSpec, UpdateCodec};
    property(40, |rng| {
        let d = rng.gen_range(1, 60);
        let n = rng.gen_range(1, 10);
        let spec = LinkSpec {
            codec: CodecKind::TopK,
            topk_frac: rng.gen_f64(0.05, 1.0),
            ..Default::default()
        };
        let mut codec = UpdateCodec::new(&spec, rng.next_u64());
        let entries: Vec<GradientEntry> = (0..n)
            .map(|sat| {
                let g: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let grad = if rng.gen_bool(0.5) {
                    codec.encode(g, &mut Vec::new())
                } else {
                    g.into()
                };
                GradientEntry { sat, staleness: rng.gen_range(0, 6), grad, n_samples: 1 }
            })
            .collect();
        let densified: Vec<GradientEntry> = entries
            .iter()
            .map(|e| GradientEntry {
                sat: e.sat,
                staleness: e.staleness,
                grad: e.grad.to_dense().into(),
                n_samples: e.n_samples,
            })
            .collect();
        let alpha = rng.gen_f64(0.0, 2.0);
        let w0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for which in 0..2 {
            let mut a = w0.clone();
            let mut b = w0.clone();
            if which == 0 {
                CpuAggregator.aggregate(&mut a, &entries, alpha).unwrap();
                CpuAggregator.aggregate(&mut b, &densified, alpha).unwrap();
            } else {
                CoordinateMedian.aggregate(&mut a, &entries, alpha).unwrap();
                CoordinateMedian.aggregate(&mut b, &densified, alpha).unwrap();
            }
            for (j, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "aggregator {which}: dim {j} (n={n} d={d})"
                );
            }
        }
    });
}

#[test]
fn no_committed_shrink_seed_files() {
    // failures reproduce via FEDSPACE_PROP_SEED alone; a committed
    // proptest-style regression corpus would silently pin stale seeds and
    // mask the env knob, so the tree must not carry one
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut stack = vec![root];
    let mut offenders = Vec::new();
    while let Some(dir) = stack.pop() {
        let Ok(rd) = std::fs::read_dir(&dir) else { continue };
        for ent in rd.flatten() {
            let p = ent.path();
            let name = ent.file_name().to_string_lossy().into_owned();
            if p.is_dir() {
                if name == "target" || name.starts_with('.') {
                    continue;
                }
                if name == "proptest-regressions" {
                    offenders.push(p);
                    continue;
                }
                stack.push(p);
            } else if name.ends_with(".proptest-regressions") || name == "prop-seeds.txt" {
                offenders.push(p);
            }
        }
    }
    assert!(offenders.is_empty(), "committed shrink-seed files: {offenders:?}");
}
