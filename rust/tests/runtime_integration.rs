//! Integration tests: the PJRT runtime executing real AOT artifacts.
//!
//! Requires the `pjrt` cargo feature (vendored `xla` crate) and
//! `make artifacts` (the Makefile's `test` target guarantees it).
#![cfg(feature = "pjrt")]
//! These tests exercise the full L3→L2→L1 path: HLO text load → PJRT
//! compile → execute, and cross-check the numerics against pure-Rust
//! oracles where one exists.

use fedspace::fl::buffer::GradientEntry;
use fedspace::fl::server::{CpuAggregator, ServerAggregator};
use fedspace::fl::staleness::normalized_weights;
use fedspace::rng::Rng;
use fedspace::runtime::ModelRuntime;
use fedspace::testing::assert_allclose;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn runtime() -> ModelRuntime {
    ModelRuntime::load(ARTIFACTS, "small").expect("run `make artifacts` first")
}

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
}

#[test]
fn loads_and_reports_meta() {
    let rt = runtime();
    assert_eq!(rt.meta.size, "small");
    assert_eq!(rt.meta.num_classes, 62);
    assert_eq!(rt.meta.img_dim, 3072);
    assert!(rt.meta.d > 0);
}

#[test]
fn init_params_layout() {
    let rt = runtime();
    let mut rng = Rng::new(0);
    let w = rt.init_params(&mut rng);
    assert_eq!(w.len(), rt.meta.d);
    // biases (tail of each layer) start at zero; weights don't
    assert!(w.iter().any(|&v| v != 0.0));
    let b2_start = rt.meta.d - rt.meta.num_classes;
    assert!(w[b2_start..].iter().all(|&v| v == 0.0), "b2 must init to zero");
}

#[test]
fn local_train_returns_finite_delta_and_loss() {
    let rt = runtime();
    let mut rng = Rng::new(1);
    let m = rt.meta.clone();
    let w = rt.init_params(&mut rng);
    let n = m.e_steps * m.batch;
    let xs = rand_vec(&mut rng, n * m.img_dim, 1.0);
    let ys: Vec<f32> = (0..n).map(|_| rng.gen_range(0, m.num_classes) as f32).collect();
    let (delta, loss) = rt.local_train(&w, &xs, &ys, 0.05).unwrap();
    assert_eq!(delta.len(), m.d);
    assert!(loss.is_finite() && loss > 0.0);
    assert!(delta.iter().all(|v| v.is_finite()));
    assert!(delta.iter().any(|&v| v != 0.0), "zero delta from SGD");
}

#[test]
fn zero_lr_gives_zero_delta() {
    let rt = runtime();
    let mut rng = Rng::new(2);
    let m = rt.meta.clone();
    let w = rt.init_params(&mut rng);
    let n = m.e_steps * m.batch;
    let xs = rand_vec(&mut rng, n * m.img_dim, 1.0);
    let ys: Vec<f32> = (0..n).map(|_| rng.gen_range(0, m.num_classes) as f32).collect();
    let (delta, _) = rt.local_train(&w, &xs, &ys, 0.0).unwrap();
    let max = delta.iter().fold(0f32, |a, &b| a.max(b.abs()));
    assert!(max < 1e-6, "max |delta| = {max}");
}

#[test]
fn local_training_reduces_loss_on_same_batch() {
    let rt = runtime();
    let mut rng = Rng::new(3);
    let m = rt.meta.clone();
    let mut w = rt.init_params(&mut rng);
    let n = m.e_steps * m.batch;
    let xs = rand_vec(&mut rng, n * m.img_dim, 1.0);
    let ys: Vec<f32> = (0..n).map(|_| rng.gen_range(0, m.num_classes) as f32).collect();
    let (_, loss0) = rt.local_train(&w, &xs, &ys, 0.0).unwrap(); // loss probe
    for _ in 0..3 {
        let (delta, _) = rt.local_train(&w, &xs, &ys, 0.5).unwrap();
        for (wi, di) in w.iter_mut().zip(delta.iter()) {
            *wi += di;
        }
    }
    let (_, loss1) = rt.local_train(&w, &xs, &ys, 0.0).unwrap();
    assert!(loss1 < loss0, "loss did not decrease: {loss0} -> {loss1}");
}

#[test]
fn uniform_logits_loss_is_log_62() {
    // zero params => uniform logits => CE = ln(62); pins the whole fwd path
    let rt = runtime();
    let mut rng = Rng::new(4);
    let m = rt.meta.clone();
    let w = vec![0.0f32; m.d];
    let n = m.e_steps * m.batch;
    let xs = rand_vec(&mut rng, n * m.img_dim, 1.0);
    let ys: Vec<f32> = (0..n).map(|_| rng.gen_range(0, m.num_classes) as f32).collect();
    let (_, loss) = rt.local_train(&w, &xs, &ys, 0.0).unwrap();
    let want = (m.num_classes as f32).ln();
    assert!((loss - want).abs() < 1e-4, "loss={loss} want={want}");
}

#[test]
fn grad_eval_matches_local_train_single_step() {
    // with E steps the first scan step's gradient equals grad_eval on the
    // same batch: delta(lr, 1 batch repeated) ≈ -lr * E-step trajectory;
    // here we only check grad_eval itself is a descent direction.
    let rt = runtime();
    let mut rng = Rng::new(5);
    let m = rt.meta.clone();
    let w = rt.init_params(&mut rng);
    let x = rand_vec(&mut rng, m.batch * m.img_dim, 1.0);
    let y: Vec<f32> = (0..m.batch).map(|_| rng.gen_range(0, m.num_classes) as f32).collect();
    let (g, loss) = rt.grad_eval(&w, &x, &y).unwrap();
    assert_eq!(g.len(), m.d);
    assert!(loss.is_finite());
    // step against the gradient reduces loss
    let lr = 0.1f32;
    let w2: Vec<f32> = w.iter().zip(g.iter()).map(|(wi, gi)| wi - lr * gi).collect();
    let (_, loss2) = rt.grad_eval(&w2, &x, &y).unwrap();
    assert!(loss2 < loss, "{loss} -> {loss2}");
}

#[test]
fn eval_batch_counts_in_range() {
    let rt = runtime();
    let mut rng = Rng::new(6);
    let m = rt.meta.clone();
    let w = rt.init_params(&mut rng);
    let x = rand_vec(&mut rng, m.eval_batch * m.img_dim, 1.0);
    let y: Vec<f32> =
        (0..m.eval_batch).map(|_| rng.gen_range(0, m.num_classes) as f32).collect();
    let (loss_sum, correct) = rt.eval_batch(&w, &x, &y).unwrap();
    assert!(loss_sum > 0.0);
    assert!(correct >= 0.0 && correct <= m.eval_batch as f32);
    assert_eq!(correct, correct.trunc());
}

#[test]
fn pjrt_aggregation_matches_cpu_oracle() {
    // The Pallas stale_aggregate artifact must equal the pure-Rust Eq. (4).
    let rt = runtime();
    let mut rng = Rng::new(7);
    let d = rt.meta.d;
    let w0 = rand_vec(&mut rng, d, 0.1);
    let entries: Vec<GradientEntry> = (0..13) // more than one chunk of 8
        .map(|sat| GradientEntry {
            sat,
            staleness: sat % 5,
            grad: rand_vec(&mut rng, d, 0.01),
            n_samples: 10,
        })
        .collect();
    let alpha = 0.5;
    let mut w_pjrt = w0.clone();
    rt.aggregate(&mut w_pjrt, &entries, alpha).unwrap();
    let mut w_cpu = w0.clone();
    CpuAggregator.aggregate(&mut w_cpu, &entries, alpha).unwrap();
    assert_allclose(&w_pjrt, &w_cpu, 1e-4, 1e-5);
}

#[test]
fn aggregate_empty_is_identity() {
    let rt = runtime();
    let mut rng = Rng::new(8);
    let w0 = rand_vec(&mut rng, rt.meta.d, 0.1);
    let mut w = w0.clone();
    rt.aggregate(&mut w, &[], 0.5).unwrap();
    assert_eq!(w, w0);
}

#[test]
fn chunk_weights_respect_staleness_order() {
    // fresher gradient moves w more than a stale one of equal magnitude
    let rt = runtime();
    let d = rt.meta.d;
    let w = vec![0.0f32; d];
    let g = vec![1.0f32; d];
    let entries = |s: usize| {
        vec![GradientEntry { sat: 0, staleness: s, grad: g.clone(), n_samples: 1 }]
    };
    // single gradient: weight is always 1 after normalization — equal
    let mut w_fresh = w.clone();
    rt.aggregate(&mut w_fresh, &entries(0), 0.5).unwrap();
    let mut w_stale = w.clone();
    rt.aggregate(&mut w_stale, &entries(4), 0.5).unwrap();
    assert_allclose(&w_fresh, &w_stale, 1e-5, 1e-6);
    // mixed: weights follow c(s)/C
    let mixed = vec![
        GradientEntry { sat: 0, staleness: 0, grad: vec![1.0; d], n_samples: 1 },
        GradientEntry { sat: 1, staleness: 3, grad: vec![-1.0; d], n_samples: 1 },
    ];
    let mut w_mixed = vec![0.0f32; d];
    rt.aggregate(&mut w_mixed, &mixed, 0.5).unwrap();
    let wts = normalized_weights(&[0, 3], 0.5);
    let want = wts[0] - wts[1];
    assert!((w_mixed[0] - want).abs() < 1e-5, "{} vs {want}", w_mixed[0]);
    assert!(w_mixed[0] > 0.0, "fresh gradient must dominate");
}

#[test]
fn deterministic_execution() {
    let rt = runtime();
    let mut rng = Rng::new(9);
    let m = rt.meta.clone();
    let w = rt.init_params(&mut rng);
    let n = m.e_steps * m.batch;
    let xs = rand_vec(&mut rng, n * m.img_dim, 1.0);
    let ys: Vec<f32> = (0..n).map(|_| rng.gen_range(0, m.num_classes) as f32).collect();
    let (d1, l1) = rt.local_train(&w, &xs, &ys, 0.05).unwrap();
    let (d2, l2) = rt.local_train(&w, &xs, &ys, 0.05).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(d1, d2);
}


mod golden {
    //! Golden cross-layer fixtures: python-computed outputs replayed
    //! through the compiled artifacts. Guards the whole interchange
    //! (HLO printer options, parser, old-XLA execution).
    use super::*;

    fn gpath(name: &str) -> String {
        format!("{ARTIFACTS}/golden_small/{name}")
    }

    fn gload(name: &str) -> Vec<f32> {
        let b = std::fs::read(gpath(name)).expect("golden fixtures: run make artifacts");
        b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
    }

    fn gscalar(key: &str) -> f32 {
        let text = std::fs::read_to_string(gpath("scalars.txt")).unwrap();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                if k == key {
                    return v.parse().unwrap();
                }
            }
        }
        panic!("missing scalar {key}");
    }

    #[test]
    fn local_train_matches_python() {
        let rt = runtime();
        let (w, xs, ys) = (gload("w.bin"), gload("xs.bin"), gload("ys.bin"));
        let (delta, loss) = rt.local_train(&w, &xs, &ys, gscalar("lr")).unwrap();
        assert!((loss - gscalar("train_loss")).abs() < 1e-3, "loss {loss}");
        assert_allclose(&delta, &gload("delta.bin"), 1e-3, 1e-4);
    }

    #[test]
    fn grad_eval_matches_python() {
        let rt = runtime();
        let w = gload("w.bin");
        let xs = gload("xs.bin");
        let ys = gload("ys.bin");
        let m = rt.meta.clone();
        let x0 = &xs[..m.batch * m.img_dim];
        let y0 = &ys[..m.batch];
        let (grad, loss) = rt.grad_eval(&w, x0, y0).unwrap();
        assert!((loss - gscalar("grad_loss")).abs() < 1e-3);
        assert_allclose(&grad, &gload("grad.bin"), 1e-3, 1e-4);
    }

    #[test]
    fn eval_step_matches_python() {
        let rt = runtime();
        let (w, xe, ye) = (gload("w.bin"), gload("xe.bin"), gload("ye.bin"));
        let (lsum, corr) = rt.eval_batch(&w, &xe, &ye).unwrap();
        assert!((lsum - gscalar("eval_loss_sum")).abs() < 2e-2, "lsum {lsum}");
        assert_eq!(corr, gscalar("eval_correct"));
    }

    #[test]
    fn no_elided_constants_in_artifacts() {
        // the bug class this guards: `constant({...})` parses as zeros
        for name in [
            "local_train_small",
            "grad_eval_small",
            "eval_step_small",
            "aggregate_chunk_small",
        ] {
            let text = std::fs::read_to_string(format!("{ARTIFACTS}/{name}.hlo.txt")).unwrap();
            assert!(!text.contains("{...}"), "{name} has an elided constant");
        }
    }
}
