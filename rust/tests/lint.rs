//! Integration tests for `fedspace lint` (ADR-0011).
//!
//! Each rule is exercised against a committed known-bad fixture tree under
//! `tests/lint_fixtures/<name>/`, asserting the exact `(file, line, rule)` of
//! every expected finding. The final test runs the linter over `src/` itself —
//! the same gate CI applies with `lint --deny` — and requires zero findings.
//!
//! Fixture directories mimic the real module layout (`fl/`, `sim/`, `app/`,
//! `cfg/`) because several rules scope by the first path component.

use fedspace::analysis::{lint_dir, LintReport, LINT_SCHEMA};
use std::path::{Path, PathBuf};

fn fixture_root(name: &str) -> PathBuf {
    let base = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures");
    base.join(name)
}

fn lint_fixture(name: &str) -> LintReport {
    lint_dir(&fixture_root(name)).expect("fixture directory scans")
}

/// Findings as comparable `(file, line, rule)` triples, in report order.
fn sites(report: &LintReport) -> Vec<(String, usize, String)> {
    report
        .findings
        .iter()
        .map(|f| (f.file.clone(), f.line, f.rule.to_string()))
        .collect()
}

fn triples(expected: &[(&str, usize, &str)]) -> Vec<(String, usize, String)> {
    expected
        .iter()
        .map(|(f, l, r)| (f.to_string(), *l, r.to_string()))
        .collect()
}

#[test]
fn wall_clock_fixture_fires_at_exact_sites() {
    let report = lint_fixture("wall_clock");
    assert_eq!(
        sites(&report),
        triples(&[
            ("app/timer.rs", 4, "wall-clock"),
            ("app/timer.rs", 9, "wall-clock"),
        ])
    );
    assert_eq!(report.suppressed, 0);
}

#[test]
fn hash_order_fixture_fires_on_both_containers() {
    let report = lint_fixture("hash_order");
    assert_eq!(
        sites(&report),
        triples(&[
            ("sim/state.rs", 4, "hash-order"),
            ("sim/state.rs", 5, "hash-order"),
        ])
    );
}

#[test]
fn rng_stream_fixture_fires_on_raw_literal_and_unnamed_ident() {
    let report = lint_fixture("rng_stream");
    assert_eq!(
        sites(&report),
        triples(&[
            ("fl/streams.rs", 4, "rng-stream"),
            ("fl/streams.rs", 8, "rng-stream"),
        ])
    );
}

#[test]
fn rng_stream_collision_reported_at_the_later_declaration() {
    let report = lint_fixture("rng_stream_dup");
    let got = sites(&report);
    assert_eq!(got, triples(&[("sim/b.rs", 2, "rng-stream")]));
    // The message names both colliding constants so the fix is obvious.
    let msg = &report.findings[0].message;
    assert!(msg.contains("BETA_STREAM"), "message was: {msg}");
    assert!(msg.contains("ALPHA_STREAM"), "message was: {msg}");
}

#[test]
fn event_coverage_fixture_finds_missing_variant_and_wildcard() {
    let report = lint_fixture("event_coverage");
    assert_eq!(
        sites(&report),
        triples(&[
            ("sim/events.rs", 7, "event-coverage"),
            ("sim/events.rs", 27, "event-coverage"),
        ])
    );
    assert!(report.findings[0].message.contains("Gamma"));
    assert!(report.findings[0].message.contains("apply"));
    assert!(report.findings[1].message.contains("wildcard"));
}

#[test]
fn float_reduce_fixture_fires_on_all_three_shapes() {
    let report = lint_fixture("float_reduce");
    assert_eq!(
        sites(&report),
        triples(&[
            ("fl/reduce.rs", 4, "float-reduce"),
            ("fl/reduce.rs", 9, "float-reduce"),
            ("fl/reduce.rs", 13, "float-reduce"),
        ])
    );
}

#[test]
fn section_registry_fixture_flags_the_unlisted_spec() {
    let report = lint_fixture("section_registry");
    let got = sites(&report);
    assert_eq!(got, triples(&[("fl/spec.rs", 5, "section-registry")]));
    assert!(report.findings[0].message.contains("GhostSpec"));
}

#[test]
fn pragma_suppresses_the_annotated_site_and_is_counted() {
    let report = lint_fixture("pragma_ok");
    assert!(report.clean(), "findings: {:?}", report.findings);
    assert_eq!(report.suppressed, 1);
}

#[test]
fn malformed_and_unknown_rule_pragmas_are_findings_themselves() {
    let report = lint_fixture("pragma_bad");
    assert_eq!(
        sites(&report),
        triples(&[
            ("app/oops.rs", 3, "pragma"),
            ("app/oops.rs", 6, "pragma"),
        ])
    );
}

#[test]
fn json_report_round_trips_through_the_parser() {
    let report = lint_fixture("event_coverage");
    let json = report.to_json();
    let doc = fedspace::bench_report::parse_json(&json).expect("lint JSON parses");
    let schema = doc.get("schema").and_then(|j| j.as_str());
    assert_eq!(schema, Some(LINT_SCHEMA));
    assert_eq!(doc.get("clean").and_then(|j| j.as_bool()), Some(false));
    let findings = doc
        .get("findings")
        .and_then(|j| j.as_arr())
        .expect("findings array");
    assert_eq!(findings.len(), 2);
    let rule = findings[0].get("rule").and_then(|j| j.as_str());
    assert_eq!(rule, Some("event-coverage"));
    let line = findings[0].get("line").and_then(|j| j.as_num());
    assert_eq!(line, Some(7.0));
    let rules = doc.get("rules").and_then(|j| j.as_arr()).expect("rule list");
    assert_eq!(rules.len(), 6);
}

/// The gate CI enforces with `cargo run -- lint --deny`: the repo's own
/// sources must produce zero findings, with every legitimate wall-clock
/// site accounted for by an audited pragma.
#[test]
fn repo_sources_lint_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_dir(&src).expect("src scans");
    assert!(
        report.clean(),
        "lint findings in src/: {}",
        report.render_text()
    );
    assert!(
        report.suppressed >= 11,
        "expected the known pragma-annotated wall-clock sites, saw {}",
        report.suppressed
    );
    assert!(report.files > 40, "too few files: {}", report.files);
}
