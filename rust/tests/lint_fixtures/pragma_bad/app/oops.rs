// Known-bad fixture: one malformed pragma, one naming an unknown rule.

// lint: allow(wall-clock)
pub fn a() {}

// lint: allow(warp-drive): engage
pub fn b() {}
