// Known-bad fixture: Gamma is never folded into TraceSink::apply, and
// to_json hides future variants behind a wildcard arm.

pub enum RunEvent {
    Alpha { step: usize },
    Beta { tick: usize },
    Gamma,
}

pub struct TraceSink;

impl TraceSink {
    pub fn apply(trace: &mut usize, event: &RunEvent) {
        match event {
            RunEvent::Alpha { .. } => {}
            RunEvent::Beta { .. } => {}
        }
    }
}

impl RunEvent {
    pub fn to_json(&self) -> String {
        match self {
            RunEvent::Alpha { .. } => String::new(),
            RunEvent::Beta { .. } => String::new(),
            RunEvent::Gamma => String::new(),
            _ => String::new(),
        }
    }
}
