// A pragma-annotated wall-clock site: lint must stay clean and count it.

pub fn measured() -> f64 {
    // lint: allow(wall-clock): operator-facing latency report
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
