// Known-bad fixture: hash-ordered containers in a deterministic module.

pub fn scratch() {
    let mut m = std::collections::HashMap::<usize, usize>::new();
    let mut s = std::collections::HashSet::<usize>::new();
    m.insert(1, 2);
    s.insert(3);
}
