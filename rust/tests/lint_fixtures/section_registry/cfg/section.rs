// The registry side of the fixture: lists OtherSpec but not GhostSpec.

#[cfg(test)]
mod tests {
    #[test]
    fn every_section_round_trips_generically() {
        roundtrip(OtherSpec::default());
    }
}
