// Known-bad fixture: GhostSpec never appears in the round-trip registry.

pub struct GhostSpec;

impl SectionSpec for GhostSpec {
    const SECTION: &'static str = "ghost";
}
