// Known-bad fixture: iteration-order-sensitive f32 reductions.

pub fn ascribed(xs: &[f32]) -> f32 {
    let total: f32 = xs.iter().sum();
    total
}

pub fn turbofish(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}

pub fn folded(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, v| a + v)
}
