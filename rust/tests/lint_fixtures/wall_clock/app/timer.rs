// Known-bad fixture: wall-clock reads outside a pragma-annotated site.

pub fn elapsed() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn epoch() -> u64 {
    let _t = std::time::SystemTime::now();
    0
}
