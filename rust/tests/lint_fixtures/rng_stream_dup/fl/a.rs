// Stream const A — its value is shared with sim/b.rs (collision fixture).
pub const ALPHA_STREAM: u64 = 0x00C0_77EE;
