// Known-bad fixture: BETA_STREAM collides numerically with ALPHA_STREAM
pub const BETA_STREAM: u64 = 0xC077EE;
