// Known-bad fixture: raw-literal and unnamed-ident seed derivations.

pub fn raw(seed: u64) -> u64 {
    seed ^ 0xBEEF
}

pub fn unnamed(run_seed: u64, mask: u64) -> u64 {
    run_seed ^ mask
}
